//! Seeded fault injection — the chaos harness the serving hardening is
//! tested against.
//!
//! Production failures come in three shapes the stack must survive:
//! a worker *panic* (a kernel bug, an assert, an OOM abort path), a
//! latency *spike* (page fault, noisy neighbor, thermal throttle), and
//! a *poisoned activation* (NaN/Inf from a bad input or a numerically
//! broken plan).  [`FaultSpec`] describes per-request probabilities for
//! each; [`FaultInjector`] turns a spec plus a seed into a
//! **deterministic schedule**: the decision for a request is a pure
//! function of `(seed, request sequence number, attempt)`.  Determinism
//! matters twice over — chaos property tests replay the exact same
//! failures on every run, and keying by `attempt` makes injected
//! failures *transient*, so the scheduler's bounded retry path is
//! genuinely exercised (a retry re-rolls the dice, exactly like a real
//! transient fault).
//!
//! The CLI grammar (`serve --faults panic:<p>,delay:<ms>:<p>,nan:<p>
//! --fault-seed S`) is parsed by [`FaultSpec::parse`].  Injected panics
//! carry [`PANIC_MARK`] in their payload so [`silence_injected_panics`]
//! can suppress their default stderr backtrace spam without hiding real
//! panics.
//!
//! Faults are injected at the dispatch layer (scheduler), not inside
//! the kernels: the point is to prove the *recovery* machinery — pool
//! panic isolation, retry-with-backoff, circuit breakers — not to
//! perturb kernel math.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// Marker embedded in every injected panic payload; the panic-hook
/// filter and log scrapers key on it.
pub const PANIC_MARK: &str = "[fault-injected]";

/// Per-request fault probabilities (all independent; a request can draw
/// a delay AND a panic).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    /// probability an execution attempt panics mid-flight
    pub panic_p: f64,
    /// injected latency spike length (ms) when the delay fault fires
    pub delay_ms: f64,
    /// probability an attempt is delayed by `delay_ms`
    pub delay_p: f64,
    /// probability a request's activations are poisoned to NaN
    pub nan_p: f64,
    /// test-only phase window: requests with sequence number >= this
    /// run fault-free.  Lets breaker-recovery tests stage a faulty
    /// phase followed by a clean one; not exposed in the CLI grammar.
    pub active_until: Option<u64>,
}

impl FaultSpec {
    /// Parse the CLI grammar: comma-separated items, each
    /// `panic:<p>`, `delay:<ms>:<p>`, or `nan:<p>` with `p` in [0, 1].
    pub fn parse(s: &str) -> Result<FaultSpec> {
        fn prob(field: Option<&str>, item: &str) -> Result<f64> {
            let raw = field
                .filter(|f| !f.is_empty())
                .with_context(|| format!("fault item {item:?} is missing its probability"))?;
            let p: f64 = raw
                .parse()
                .with_context(|| format!("bad probability {raw:?} in fault item {item:?}"))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("probability {p} out of [0, 1] in fault item {item:?}");
            }
            Ok(p)
        }
        let mut spec = FaultSpec::default();
        for item in s.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let mut fields = item.split(':');
            let kind = fields.next().unwrap_or("");
            match kind {
                "panic" => spec.panic_p = prob(fields.next(), item)?,
                "nan" => spec.nan_p = prob(fields.next(), item)?,
                "delay" => {
                    let raw = fields
                        .next()
                        .filter(|f| !f.is_empty())
                        .with_context(|| format!("delay item {item:?} wants delay:<ms>:<p>"))?;
                    let ms: f64 = raw
                        .parse()
                        .with_context(|| format!("bad delay ms {raw:?} in {item:?}"))?;
                    if !ms.is_finite() || ms < 0.0 {
                        bail!("delay ms must be finite and >= 0, got {ms} in {item:?}");
                    }
                    spec.delay_ms = ms;
                    spec.delay_p = prob(fields.next(), item)?;
                }
                other => bail!(
                    "unknown fault kind {other:?} in {item:?} \
                     (grammar: panic:<p>,delay:<ms>:<p>,nan:<p>)"
                ),
            }
            if fields.next().is_some() {
                bail!("trailing fields in fault item {item:?}");
            }
        }
        Ok(spec)
    }

    /// No fault can ever fire under this spec.
    pub fn is_noop(&self) -> bool {
        self.panic_p <= 0.0 && self.nan_p <= 0.0 && (self.delay_p <= 0.0 || self.delay_ms <= 0.0)
    }

    /// One-line human summary for banners and reports.
    pub fn summary(&self) -> String {
        format!(
            "panic:{} delay:{}ms:{} nan:{}",
            self.panic_p, self.delay_ms, self.delay_p, self.nan_p
        )
    }
}

/// What the schedule decided for one `(request, attempt)` pair.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultDecision {
    /// panic mid-execution (after any delay, before any result)
    pub panic: bool,
    /// sleep this long before executing
    pub delay: Option<Duration>,
    /// poison the request's input image to all-NaN
    pub nan: bool,
}

impl FaultDecision {
    pub fn is_clean(&self) -> bool {
        !self.panic && !self.nan && self.delay.is_none()
    }
}

/// The seeded schedule: `decide(seq, attempt)` is pure, so any replay
/// with the same seed sees the same faults — and a different `attempt`
/// re-rolls, making injected failures transient under retry.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    spec: FaultSpec,
    seed: u64,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec, seed: u64) -> FaultInjector {
        FaultInjector { spec, seed }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// The fault decision for dispatch sequence number `seq`, execution
    /// attempt `attempt` (0 = first try).
    pub fn decide(&self, seq: u64, attempt: u32) -> FaultDecision {
        if let Some(until) = self.spec.active_until {
            if seq >= until {
                return FaultDecision::default();
            }
        }
        // distinct multipliers keep the seq and attempt axes from
        // aliasing (same constants as Rng::fork)
        let mut rng = Rng::new(
            self.seed
                ^ seq.wrapping_mul(0xA24BAED4963EE407)
                ^ (attempt as u64 + 1).wrapping_mul(0x9FB21C651E98DF25),
        );
        // fixed draw order so adding a fault kind never reshuffles the
        // schedule of the others
        let panic = (rng.uniform() as f64) < self.spec.panic_p;
        let delayed = (rng.uniform() as f64) < self.spec.delay_p && self.spec.delay_ms > 0.0;
        let nan = (rng.uniform() as f64) < self.spec.nan_p;
        FaultDecision {
            panic,
            delay: delayed.then(|| Duration::from_secs_f64(self.spec.delay_ms / 1e3)),
            nan,
        }
    }
}

/// Panic with the injected-fault marker — always routed here so the
/// payload shape is uniform for the hook filter and for tests.
pub fn injected_panic(seq: u64, attempt: u32) -> ! {
    panic!("{PANIC_MARK} injected worker panic (request {seq}, attempt {attempt})");
}

/// Poison an activation buffer the way a numerically broken plan would:
/// every element NaN, so the forward pass cannot launder it back to a
/// finite logit (single-element poison can be absorbed by max-pooling).
pub fn poison_nan(buf: &mut [f32]) {
    buf.fill(f32::NAN);
}

/// Install a process-wide panic hook that suppresses the default stderr
/// report for *injected* panics (payload contains [`PANIC_MARK`]) and
/// delegates everything else to the previous hook.  Idempotent; chaos
/// runs call this once so a high `panic:<p>` doesn't bury real output
/// under backtrace spam.  Real panics still print.
pub fn silence_injected_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .is_some_and(|m| m.contains(PANIC_MARK));
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let s = FaultSpec::parse("panic:0.05,delay:2:0.1,nan:0.01").unwrap();
        assert_eq!(s.panic_p, 0.05);
        assert_eq!(s.delay_ms, 2.0);
        assert_eq!(s.delay_p, 0.1);
        assert_eq!(s.nan_p, 0.01);
        assert!(s.active_until.is_none());
        assert!(!s.is_noop());
    }

    #[test]
    fn parse_partial_and_empty() {
        let s = FaultSpec::parse("panic:1").unwrap();
        assert_eq!(s.panic_p, 1.0);
        assert_eq!(s.nan_p, 0.0);
        assert!(FaultSpec::parse("").unwrap().is_noop());
        // zero-probability items are noops even when present
        assert!(FaultSpec::parse("panic:0,delay:5:0,nan:0").unwrap().is_noop());
        // delay with ms but p=0 never fires
        assert!(FaultSpec::parse("delay:5:0").unwrap().is_noop());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultSpec::parse("panic:1.5").is_err(), "p > 1");
        assert!(FaultSpec::parse("panic:-0.1").is_err(), "p < 0");
        assert!(FaultSpec::parse("panic").is_err(), "missing p");
        assert!(FaultSpec::parse("delay:2").is_err(), "delay missing p");
        assert!(FaultSpec::parse("delay:-1:0.5").is_err(), "negative ms");
        assert!(FaultSpec::parse("oom:0.5").is_err(), "unknown kind");
        assert!(FaultSpec::parse("panic:0.5:7").is_err(), "trailing field");
        assert!(FaultSpec::parse("panic:abc").is_err(), "non-numeric p");
    }

    #[test]
    fn decisions_are_deterministic_and_axis_sensitive() {
        let spec = FaultSpec::parse("panic:0.5,delay:1:0.5,nan:0.5").unwrap();
        let inj = FaultInjector::new(spec.clone(), 42);
        let again = FaultInjector::new(spec, 42);
        let mut seq_varies = false;
        let mut attempt_varies = false;
        for seq in 0..64u64 {
            for attempt in 0..4u32 {
                let d = inj.decide(seq, attempt);
                assert_eq!(d, again.decide(seq, attempt), "replay must match");
                if d != inj.decide(seq + 64, attempt) {
                    seq_varies = true;
                }
                if d != inj.decide(seq, attempt + 4) {
                    attempt_varies = true;
                }
            }
        }
        assert!(seq_varies, "schedule must differ across requests");
        assert!(attempt_varies, "schedule must differ across attempts (transient faults)");
    }

    #[test]
    fn probability_extremes_are_exact() {
        let always = FaultInjector::new(FaultSpec::parse("panic:1,nan:1").unwrap(), 7);
        let never = FaultInjector::new(FaultSpec::parse("panic:0,delay:3:0,nan:0").unwrap(), 7);
        for seq in 0..256u64 {
            let d = always.decide(seq, 0);
            assert!(d.panic && d.nan, "p=1 must always fire");
            assert!(never.decide(seq, 0).is_clean(), "p=0 must never fire");
        }
    }

    #[test]
    fn active_until_windows_the_schedule() {
        let mut spec = FaultSpec::parse("panic:1").unwrap();
        spec.active_until = Some(10);
        let inj = FaultInjector::new(spec, 3);
        for seq in 0..10u64 {
            assert!(inj.decide(seq, 0).panic, "inside the window");
        }
        for seq in 10..40u64 {
            assert!(inj.decide(seq, 0).is_clean(), "past the window");
        }
    }

    #[test]
    fn injected_panic_carries_the_marker() {
        silence_injected_panics();
        let err = std::panic::catch_unwind(|| injected_panic(3, 1)).unwrap_err();
        let msg = err.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains(PANIC_MARK), "payload {msg:?} missing marker");
        assert!(msg.contains("request 3"), "payload should name the request");
    }

    #[test]
    fn poison_fills_every_element() {
        let mut buf = vec![1.0f32; 17];
        poison_nan(&mut buf);
        assert!(buf.iter().all(|v| v.is_nan()));
    }
}
