//! Admission control — the piece that turns unbounded queueing into
//! bounded tail latency.
//!
//! Two independent gates, both explicit (a shed request gets a
//! [`super::scheduler::Reply::Rejected`], never silence):
//!
//! * **Queue-depth cap** (`shed_depth`): a new arrival is rejected when
//!   the scheduler's queue already holds that many requests.  This is
//!   the backpressure bound — without it a burst makes the queue (and
//!   therefore every later request's wait) arbitrarily long.
//! * **Deadline viability**, checked at *dispatch* time: a request
//!   whose age plus the active plan's estimated execution time already
//!   exceeds its deadline cannot possibly be answered within the SLO,
//!   so executing it would only burn capacity that on-time requests
//!   need.  Shedding it keeps the served-latency distribution inside
//!   the budget the planner promised.
//!
//! Per-request deadlines override the config default; a request with
//! neither is never deadline-shed.

use std::time::{Duration, Instant};

/// Why a request was rejected instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// queue was at its depth cap on arrival
    QueueFull,
    /// deadline unmeetable at dispatch (age + estimated exec > budget)
    Deadline,
    /// malformed request (wrong image element count)
    Malformed,
    /// server-side execution error — the request was fine, the engine
    /// failed (the reply contract still owes the client an answer)
    Internal,
    /// execution failed and the deadline could not fit another retry
    /// attempt — the SLO-derived execution timeout
    Timeout,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Deadline => "deadline",
            ShedReason::Malformed => "malformed",
            ShedReason::Internal => "internal",
            ShedReason::Timeout => "timeout",
        }
    }

    /// The metrics-registry counter this shed reason increments
    /// (`requests_shed_<name>`); pinned by `ServeStats::diff_registry`.
    pub fn counter_name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "requests_shed_queue_full",
            ShedReason::Deadline => "requests_shed_deadline",
            ShedReason::Malformed => "requests_shed_malformed",
            ShedReason::Internal => "requests_shed_internal",
            ShedReason::Timeout => "requests_shed_timeout",
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct AdmissionCfg {
    /// max requests waiting in the scheduler queue; 0 = unbounded
    /// (the legacy drain behavior)
    pub shed_depth: usize,
    /// default per-request latency budget; None = no deadline shedding
    pub deadline: Option<Duration>,
}

impl AdmissionCfg {
    /// Unbounded queue, no deadlines — byte-for-byte the legacy loop.
    pub fn open() -> AdmissionCfg {
        AdmissionCfg::default()
    }

    /// Cap + SLO-derived deadline in one call (the CLI path).
    pub fn slo(shed_depth: usize, slo_ms: f64) -> AdmissionCfg {
        AdmissionCfg {
            shed_depth,
            deadline: (slo_ms > 0.0).then(|| Duration::from_secs_f64(slo_ms / 1e3)),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Admission {
    pub cfg: AdmissionCfg,
}

impl Admission {
    pub fn new(cfg: AdmissionCfg) -> Admission {
        Admission { cfg }
    }

    /// Arrival gate: may a new request join a queue of `depth` waiters?
    pub fn admit(&self, depth: usize) -> Result<(), ShedReason> {
        if self.cfg.shed_depth > 0 && depth >= self.cfg.shed_depth {
            return Err(ShedReason::QueueFull);
        }
        Ok(())
    }

    /// The effective deadline for a request submitted at `submitted`
    /// with an optional explicit per-request deadline.
    pub fn deadline_for(&self, submitted: Instant, explicit: Option<Instant>) -> Option<Instant> {
        explicit.or_else(|| self.cfg.deadline.map(|d| submitted + d))
    }

    /// Dispatch gate: can this request still meet its deadline if
    /// execution starts now and takes `est_exec`?
    pub fn viable(
        &self,
        submitted: Instant,
        explicit: Option<Instant>,
        now: Instant,
        est_exec: Duration,
    ) -> Result<(), ShedReason> {
        match self.deadline_for(submitted, explicit) {
            Some(d) if now + est_exec > d => Err(ShedReason::Deadline),
            _ => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_admission_never_sheds() {
        let a = Admission::new(AdmissionCfg::open());
        assert!(a.admit(0).is_ok());
        assert!(a.admit(1_000_000).is_ok());
        let now = Instant::now();
        assert!(a.viable(now, None, now + Duration::from_secs(60), Duration::ZERO).is_ok());
    }

    #[test]
    fn queue_cap_sheds_at_depth() {
        let a = Admission::new(AdmissionCfg { shed_depth: 4, deadline: None });
        assert!(a.admit(3).is_ok());
        assert_eq!(a.admit(4), Err(ShedReason::QueueFull));
        assert_eq!(a.admit(100), Err(ShedReason::QueueFull));
    }

    #[test]
    fn deadline_viability_accounts_for_exec_estimate() {
        let a = Admission::new(AdmissionCfg::slo(0, 10.0));
        let t0 = Instant::now();
        let exec = Duration::from_millis(4);
        // 2 ms old + 4 ms exec < 10 ms budget: viable
        assert!(a.viable(t0, None, t0 + Duration::from_millis(2), exec).is_ok());
        // 8 ms old + 4 ms exec > 10 ms budget: shed
        assert_eq!(
            a.viable(t0, None, t0 + Duration::from_millis(8), exec),
            Err(ShedReason::Deadline)
        );
        // an explicit per-request deadline wins over the config default
        let long = Some(t0 + Duration::from_secs(5));
        assert!(a.viable(t0, long, t0 + Duration::from_millis(8), exec).is_ok());
    }

    #[test]
    fn slo_zero_means_no_deadline() {
        let a = Admission::new(AdmissionCfg::slo(8, 0.0));
        assert!(a.cfg.deadline.is_none());
        assert_eq!(a.cfg.shed_depth, 8);
        assert_eq!(ShedReason::Deadline.name(), "deadline");
        assert_eq!(ShedReason::QueueFull.name(), "queue_full");
        assert_eq!(ShedReason::Malformed.name(), "malformed");
        assert_eq!(ShedReason::Internal.name(), "internal");
        assert_eq!(ShedReason::Timeout.name(), "timeout");
    }
}
