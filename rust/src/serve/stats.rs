//! Serving statistics — latency percentiles, shed accounting, and the
//! plan-switch trail, shared by every scheduler policy and by the
//! legacy PJRT drain loop.
//!
//! All derived metrics are total functions: with ZERO recorded requests
//! `throughput()`, `mean_batch()`, and `percentile_ms()` return 0.0
//! instead of dividing by zero or indexing an empty sorted view — a
//! fully-shed overload run must still render a report.

use std::time::Duration;

use crate::obs::metrics::{LogHistogram, Registry};
use crate::serve::admission::ShedReason;
use crate::util::json::Json;

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// requests executed and answered with [`super::scheduler::Reply::Served`]
    pub served: usize,
    /// dispatch waves (batches or steal waves) that ran the network
    pub batches: usize,
    /// requests shed at admission because the queue was at its depth cap
    pub shed_queue: usize,
    /// requests shed at dispatch because their deadline was unmeetable
    pub shed_deadline: usize,
    /// requests rejected as malformed (wrong image size)
    pub shed_malformed: usize,
    /// requests answered Rejected because the engine itself failed
    /// (execution attempts exhausted)
    pub shed_internal: usize,
    /// requests whose failed execution could not be retried within the
    /// SLO-derived deadline
    pub shed_timeout: usize,
    /// replies whose receiver hung up before the send (the reply was
    /// produced and counted, the client just stopped listening)
    pub reply_dropped: usize,
    /// execution re-attempts taken after a failed attempt
    pub retries: usize,
    /// execution attempts that failed (panic, error, non-finite logits)
    pub exec_failures: usize,
    /// circuit-breaker Open transitions (plan taken out of rotation)
    pub breaker_trips: usize,
    /// circuit-breaker Close transitions (half-open probe succeeded)
    pub breaker_recoveries: usize,
    /// `(wave_index, plan, event)` trail of breaker transitions
    pub breaker_log: Vec<(usize, usize, &'static str)>,
    /// plan switches the SLO controller performed
    pub plan_switches: usize,
    /// served-request count per plan index (empty until first dispatch)
    pub served_per_plan: Vec<usize>,
    /// `(wave_index, from_plan, to_plan)` trail of controller switches
    pub switch_log: Vec<(usize, usize, usize)>,
    /// raw samples; private so the only writer is `record()` — the
    /// sorted cache below is invalidated by length, which is airtight
    /// exactly because nothing can mutate samples in place
    latencies_ms: Vec<f64>,
    pub wall: Duration,
    /// sorted view of `latencies_ms`, built lazily on the first
    /// exact-percentile query and reused until the samples change
    sorted_cache: std::cell::RefCell<Vec<f64>>,
    /// log-bucketed latency histogram fed in lockstep with
    /// `latencies_ms` — the O(1)-record path `percentile_ms` reads;
    /// the sorted vector stays as the exact reference behind
    /// [`ServeStats::percentile_ms_exact`] and the agreement tests
    lat_hist: LogHistogram,
}

impl ServeStats {
    /// Stats with per-plan counters sized for an `n_plans` engine.
    pub fn with_plans(n_plans: usize) -> ServeStats {
        ServeStats { served_per_plan: vec![0; n_plans], ..Default::default() }
    }

    pub fn record(&mut self, latency_ms: f64) {
        self.latencies_ms.push(latency_ms);
        self.lat_hist.record(latency_ms);
        self.served += 1;
    }

    /// Record a served request against the plan that executed it.
    pub fn record_on_plan(&mut self, latency_ms: f64, plan: usize) {
        self.record(latency_ms);
        if plan >= self.served_per_plan.len() {
            self.served_per_plan.resize(plan + 1, 0);
        }
        self.served_per_plan[plan] += 1;
    }

    pub fn shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::QueueFull => self.shed_queue += 1,
            ShedReason::Deadline => self.shed_deadline += 1,
            ShedReason::Malformed => self.shed_malformed += 1,
            ShedReason::Internal => self.shed_internal += 1,
            ShedReason::Timeout => self.shed_timeout += 1,
        }
    }

    /// Requests rejected for any reason.
    pub fn shed_total(&self) -> usize {
        self.shed_queue
            + self.shed_deadline
            + self.shed_malformed
            + self.shed_internal
            + self.shed_timeout
    }

    /// Requests that got SOME reply (served or rejected).
    pub fn offered(&self) -> usize {
        self.served + self.shed_total()
    }

    /// Fraction of offered requests that were shed (0.0 when idle).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            return 0.0;
        }
        self.shed_total() as f64 / offered as f64
    }

    /// Percentile off the log-bucketed histogram: O(1) per recorded
    /// sample, one bucket walk per query, within ~1% relative error of
    /// [`ServeStats::percentile_ms_exact`] (agreement is pinned by a
    /// seeded test below).  p0/p100 are exact; non-finite samples are
    /// excluded.  0.0 with no recorded requests.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.lat_hist.percentile(p)
    }

    /// The exact interpolating percentile over a cached sorted view —
    /// the pre-histogram reference path, kept for tests and for
    /// anything that needs the true order statistic (re-sorts once per
    /// sample-count change, so recording is no longer O(1) amortized
    /// if this is queried per window).
    pub fn percentile_ms_exact(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut cache = self.sorted_cache.borrow_mut();
        if cache.len() != self.latencies_ms.len() {
            *cache = self.latencies_ms.clone();
            // total_cmp: a NaN sample (clock anomaly, injected fault)
            // must not panic the report path — it sorts last
            cache.sort_by(|a, b| a.total_cmp(b));
        }
        percentile_sorted(&cache, p)
    }

    /// Served requests per second of wall time; 0.0 when nothing ran.
    pub fn throughput(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.served as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Mean served requests per dispatch wave; 0.0 before any wave.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.served as f64 / self.batches as f64
    }

    /// The fault-and-resilience section of the report, grouped so the
    /// serve JSON is the single fleet-level record (ROADMAP item 3).
    /// These are the same counters the scheduler mirrors into its
    /// metrics [`Registry`] — [`ServeStats::agrees_with_registry`]
    /// pins the two accountings against each other.
    fn faults_json(&self) -> Json {
        Json::obj_from(vec![
            ("retries", Json::int(self.retries as i64)),
            ("exec_failures", Json::int(self.exec_failures as i64)),
            ("breaker_trips", Json::int(self.breaker_trips as i64)),
            ("breaker_recoveries", Json::int(self.breaker_recoveries as i64)),
            ("reply_dropped", Json::int(self.reply_dropped as i64)),
            (
                "shed_by_reason",
                Json::obj_from(vec![
                    ("queue_full", Json::int(self.shed_queue as i64)),
                    ("deadline", Json::int(self.shed_deadline as i64)),
                    ("malformed", Json::int(self.shed_malformed as i64)),
                    ("internal", Json::int(self.shed_internal as i64)),
                    ("timeout", Json::int(self.shed_timeout as i64)),
                ]),
            ),
        ])
    }

    /// Cross-check this stats object against the scheduler's metrics
    /// registry: every request/shed/retry/breaker counter must match
    /// exactly (the two are incremented on independent code paths).
    /// Returns the first mismatch as `(name, stats_value,
    /// registry_value)`, or `None` when they agree.
    pub fn diff_registry(&self, reg: &Registry) -> Option<(&'static str, u64, u64)> {
        let pairs: [(&'static str, u64); 13] = [
            ("requests_offered", self.offered() as u64),
            ("requests_served", self.served as u64),
            ("requests_shed_queue_full", self.shed_queue as u64),
            ("requests_shed_deadline", self.shed_deadline as u64),
            ("requests_shed_malformed", self.shed_malformed as u64),
            ("requests_shed_internal", self.shed_internal as u64),
            ("requests_shed_timeout", self.shed_timeout as u64),
            ("exec_retries", self.retries as u64),
            ("exec_failures", self.exec_failures as u64),
            ("breaker_trips", self.breaker_trips as u64),
            ("breaker_recoveries", self.breaker_recoveries as u64),
            ("plan_switches", self.plan_switches as u64),
            ("reply_dropped", self.reply_dropped as u64),
        ];
        pairs
            .into_iter()
            .find(|&(name, v)| reg.counter(name) != v)
            .map(|(name, v)| (name, v, reg.counter(name)))
    }

    /// The serve report record: one JSON object per run, written by the
    /// CLI next to the frontier CSVs and by `bench_serve`.
    pub fn report_json(&self, policy: &str, slo_ms: f64) -> Json {
        Json::obj_from(vec![
            ("policy", Json::str_of(policy)),
            ("slo_ms", Json::num(slo_ms)),
            ("served", Json::int(self.served as i64)),
            ("batches", Json::int(self.batches as i64)),
            ("shed_queue", Json::int(self.shed_queue as i64)),
            ("shed_deadline", Json::int(self.shed_deadline as i64)),
            ("shed_malformed", Json::int(self.shed_malformed as i64)),
            ("shed_internal", Json::int(self.shed_internal as i64)),
            ("shed_timeout", Json::int(self.shed_timeout as i64)),
            ("reply_dropped", Json::int(self.reply_dropped as i64)),
            ("retries", Json::int(self.retries as i64)),
            ("exec_failures", Json::int(self.exec_failures as i64)),
            ("breaker_trips", Json::int(self.breaker_trips as i64)),
            ("breaker_recoveries", Json::int(self.breaker_recoveries as i64)),
            ("faults", self.faults_json()),
            ("shed_rate", Json::num(self.shed_rate())),
            ("p50_ms", Json::num(self.percentile_ms(0.5))),
            ("p95_ms", Json::num(self.percentile_ms(0.95))),
            ("p99_ms", Json::num(self.percentile_ms(0.99))),
            ("throughput_rps", Json::num(self.throughput())),
            ("mean_batch", Json::num(self.mean_batch())),
            ("plan_switches", Json::int(self.plan_switches as i64)),
            (
                "served_per_plan",
                Json::arr_of(self.served_per_plan.iter().map(|&n| Json::int(n as i64))),
            ),
            (
                "switch_log",
                Json::arr_of(self.switch_log.iter().map(|&(w, from, to)| {
                    Json::arr_of([
                        Json::int(w as i64),
                        Json::int(from as i64),
                        Json::int(to as i64),
                    ])
                })),
            ),
            (
                "breaker_log",
                Json::arr_of(self.breaker_log.iter().map(|&(w, plan, ev)| {
                    Json::arr_of([
                        Json::int(w as i64),
                        Json::int(plan as i64),
                        Json::str_of(ev),
                    ])
                })),
            ),
        ])
    }

    #[cfg(test)]
    pub(crate) fn set_samples(&mut self, samples: Vec<f64>) {
        self.lat_hist = LogHistogram::new();
        for &v in &samples {
            self.lat_hist.record(v);
        }
        self.latencies_ms = samples;
    }
}

/// Interpolating percentile over an ALREADY-SORTED slice — THE
/// percentile definition for the serving subsystem (`ServeStats` and
/// the scheduler's controller window both route here, so the p95 the
/// controller acts on is the same statistic the reports print).
/// Returns 0.0 on an empty slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (v.len() - 1) as f64 * p.clamp(0.0, 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_requests_yield_zero_not_nan() {
        // the satellite pin: every derived metric is total on the empty
        // stats a fully-shed run produces
        let s = ServeStats::default();
        assert_eq!(s.percentile_ms(0.5), 0.0);
        assert_eq!(s.percentile_ms(0.99), 0.0);
        assert_eq!(s.throughput(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
        assert_eq!(s.shed_rate(), 0.0);
        assert!(s.percentile_ms(0.5).is_finite());
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServeStats::default();
        s.set_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        s.served = 5;
        s.batches = 2;
        s.wall = Duration::from_secs(1);
        assert_eq!(s.percentile_ms_exact(0.5), 3.0);
        // histogram path: within bucket error of the exact statistic
        assert!((s.percentile_ms(0.5) - 3.0).abs() / 3.0 < 0.02);
        assert!(s.percentile_ms(0.95) >= 4.0);
        assert_eq!(s.throughput(), 5.0);
        assert_eq!(s.mean_batch(), 2.5);
    }

    #[test]
    fn percentiles_interpolate_and_cover_tails() {
        // pin p50/p95/p99 on a known 1..=100 sample: rank = 99 * p,
        // linear interpolation between order statistics (the exact
        // sorted-vec path kept behind tests)
        let mut s = ServeStats::default();
        s.set_samples((1..=100).rev().map(|x| x as f64).collect());
        assert!((s.percentile_ms_exact(0.50) - 50.5).abs() < 1e-12);
        assert!((s.percentile_ms_exact(0.95) - 95.05).abs() < 1e-12);
        assert!((s.percentile_ms_exact(0.99) - 99.01).abs() < 1e-12);
        assert_eq!(s.percentile_ms_exact(0.0), 1.0);
        assert_eq!(s.percentile_ms_exact(1.0), 100.0);
        // the histogram path pins the tails exactly too
        assert_eq!(s.percentile_ms(0.0), 1.0);
        assert_eq!(s.percentile_ms(1.0), 100.0);

        // the old truncating index underestimated the tail: on 5
        // samples it returned 4.0 for p95 — now nearly the max
        let mut t = ServeStats::default();
        t.set_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert!((t.percentile_ms_exact(0.95) - 80.8).abs() < 1e-9);

        // degenerate single sample
        let mut one = ServeStats::default();
        one.set_samples(vec![7.0]);
        assert_eq!(one.percentile_ms_exact(0.99), 7.0);
        assert_eq!(one.percentile_ms(0.99), 7.0);
    }

    #[test]
    fn sorted_cache_tracks_new_samples() {
        let mut s = ServeStats::default();
        s.record(5.0);
        s.record(1.0);
        assert_eq!(s.percentile_ms_exact(0.0), 1.0);
        assert_eq!(s.percentile_ms_exact(1.0), 5.0);
        // appending invalidates the cached view (length changes)
        s.record(0.5);
        assert_eq!(s.percentile_ms_exact(0.0), 0.5);
        // record() feeds the histogram in lockstep
        assert_eq!(s.percentile_ms(0.0), 0.5);
        assert_eq!(s.percentile_ms(1.0), 5.0);
        assert_eq!(s.served, 3);
    }

    #[test]
    fn histogram_tracks_exact_percentiles_within_bucket_error() {
        // the satellite pin: the O(1) histogram path agrees with the
        // exact order statistic within the log-bucket relative error
        // on a seeded heavy-tailed trace
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        let mut s = ServeStats::default();
        for _ in 0..5000 {
            // lognormal-ish: sub-ms floor with a long tail
            let v = 0.2 + (rng.uniform() as f64) * 3.0 + (rng.normal() as f64).exp();
            s.record(v.abs().max(1e-3));
        }
        for p in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let exact = s.percentile_ms_exact(p);
            let approx = s.percentile_ms(p);
            let rel = (approx - exact).abs() / exact;
            assert!(
                rel < 0.02,
                "p{p}: histogram {approx} vs exact {exact} (rel {rel})"
            );
        }
    }

    #[test]
    fn shed_counters_and_rate() {
        let mut s = ServeStats::with_plans(2);
        s.record_on_plan(1.0, 0);
        s.record_on_plan(2.0, 1);
        s.record_on_plan(3.0, 1);
        s.shed(ShedReason::QueueFull);
        s.shed(ShedReason::QueueFull);
        s.shed(ShedReason::Deadline);
        assert_eq!(s.shed_total(), 3);
        assert_eq!(s.offered(), 6);
        assert!((s.shed_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.served_per_plan, vec![1, 2]);
        // record_on_plan grows the per-plan table when a late switch
        // lands on an index the constructor never saw
        s.record_on_plan(1.0, 3);
        assert_eq!(s.served_per_plan, vec![1, 2, 0, 1]);
    }

    #[test]
    fn nan_latency_sample_does_not_panic_percentiles() {
        // the total_cmp satellite: the old partial_cmp().unwrap() sort
        // aborted the whole report on one NaN sample
        let mut s = ServeStats::default();
        s.set_samples(vec![3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.percentile_ms_exact(0.0), 1.0);
        // NaN orders last under total_cmp, so exact p100 is NaN — ugly
        // but honest, and crucially not a panic
        assert!(s.percentile_ms_exact(1.0).is_nan());
        assert_eq!(s.percentile_ms_exact(0.5), 2.5);
        // the histogram path excludes non-finite samples outright, so
        // the report percentiles stay finite under a clock anomaly
        assert_eq!(s.percentile_ms(1.0), 3.0);
        assert!(s.percentile_ms(0.5).is_finite());
    }

    #[test]
    fn fault_counters_feed_shed_total_and_report() {
        let mut s = ServeStats::default();
        s.shed(ShedReason::Timeout);
        s.shed(ShedReason::Internal);
        assert_eq!(s.shed_timeout, 1);
        assert_eq!(s.shed_total(), 2);
        s.reply_dropped = 3;
        s.retries = 4;
        s.exec_failures = 5;
        s.breaker_trips = 2;
        s.breaker_recoveries = 1;
        s.breaker_log.push((7, 0, "open"));
        s.breaker_log.push((9, 0, "close"));
        let j = s.report_json("steal", 5.0);
        assert_eq!(j.get("shed_timeout").unwrap().f64().unwrap(), 1.0);
        assert_eq!(j.get("reply_dropped").unwrap().f64().unwrap(), 3.0);
        assert_eq!(j.get("retries").unwrap().f64().unwrap(), 4.0);
        assert_eq!(j.get("exec_failures").unwrap().f64().unwrap(), 5.0);
        assert_eq!(j.get("breaker_trips").unwrap().f64().unwrap(), 2.0);
        assert_eq!(j.get("breaker_recoveries").unwrap().f64().unwrap(), 1.0);
        let log = j.get("breaker_log").unwrap().arr().unwrap();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].arr().unwrap()[2].str().unwrap(), "open");
        // the grouped faults{} section mirrors the flat counters
        let f = j.get("faults").unwrap();
        assert_eq!(f.get("retries").unwrap().usize().unwrap(), 4);
        assert_eq!(f.get("exec_failures").unwrap().usize().unwrap(), 5);
        assert_eq!(f.get("breaker_trips").unwrap().usize().unwrap(), 2);
        assert_eq!(f.get("reply_dropped").unwrap().usize().unwrap(), 3);
        let by = f.get("shed_by_reason").unwrap();
        assert_eq!(by.get("timeout").unwrap().usize().unwrap(), 1);
        assert_eq!(by.get("internal").unwrap().usize().unwrap(), 1);
        assert_eq!(by.get("queue_full").unwrap().usize().unwrap(), 0);
        // round-trips through the parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("breaker_log").unwrap().arr().unwrap().len(), 2);
        assert_eq!(
            back.get("faults").unwrap().get("retries").unwrap().usize().unwrap(),
            4
        );
    }

    #[test]
    fn diff_registry_finds_drift_and_accepts_agreement() {
        let mut s = ServeStats::default();
        s.record(1.0);
        s.shed(ShedReason::QueueFull);
        s.retries = 2;
        let reg = Registry::new();
        reg.counter_add("requests_offered", 2);
        reg.counter_add("requests_served", 1);
        reg.counter_add("requests_shed_queue_full", 1);
        reg.counter_add("exec_retries", 2);
        assert_eq!(s.diff_registry(&reg), None);
        reg.counter_add("exec_retries", 1);
        assert_eq!(s.diff_registry(&reg), Some(("exec_retries", 2, 3)));
    }

    #[test]
    fn report_json_carries_shed_and_switches() {
        let mut s = ServeStats::with_plans(2);
        s.record_on_plan(4.0, 0);
        s.shed(ShedReason::Deadline);
        s.plan_switches = 1;
        s.switch_log.push((3, 0, 1));
        s.batches = 1;
        s.wall = Duration::from_millis(10);
        let j = s.report_json("steal", 5.0);
        assert_eq!(j.get("policy").unwrap().str().unwrap(), "steal");
        assert_eq!(j.get("shed_deadline").unwrap().f64().unwrap(), 1.0);
        assert_eq!(j.get("plan_switches").unwrap().f64().unwrap(), 1.0);
        assert_eq!(j.get("switch_log").unwrap().arr().unwrap().len(), 1);
        // round-trips through the parser
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("served").unwrap().f64().unwrap(), 1.0);
    }
}
