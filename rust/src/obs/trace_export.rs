//! Chrome trace-event JSON writer.
//!
//! Serializes the span recorder's events into the trace-event format
//! that `chrome://tracing` and [Perfetto](https://ui.perfetto.dev)
//! load directly: one object with `displayTimeUnit` and a
//! `traceEvents` array of `ph: "X"` complete events (ts/dur in
//! microseconds), `ph: "i"` instants, and `ph: "M"` thread-name
//! metadata so pool workers show up as named tracks.  Everything runs
//! under one synthetic pid (this is a single-process runtime); tids
//! are the recorder's per-thread ids.

use std::path::Path;

use anyhow::{Context, Result};

use crate::obs::span::{self, EventKind, SpanEvent};
use crate::util::json::Json;

/// The synthetic process id every event is filed under.
const PID: i64 = 1;

fn meta_thread_name(tid: u64, name: &str) -> Json {
    Json::obj_from(vec![
        ("ph", Json::str_of("M")),
        ("name", Json::str_of("thread_name")),
        ("pid", Json::int(PID)),
        ("tid", Json::int(tid as i64)),
        (
            "args",
            Json::obj_from(vec![("name", Json::str_of(name))]),
        ),
    ])
}

fn trace_event(e: &SpanEvent) -> Json {
    let mut fields = vec![
        ("name", Json::str_of(e.name)),
        ("cat", Json::str_of(e.cat)),
        ("pid", Json::int(PID)),
        ("tid", Json::int(e.tid as i64)),
        ("ts", Json::int(e.t0_us as i64)),
    ];
    match e.kind {
        EventKind::Complete => {
            fields.push(("ph", Json::str_of("X")));
            fields.push(("dur", Json::int(e.dur_us as i64)));
        }
        EventKind::Instant => {
            fields.push(("ph", Json::str_of("i")));
            // Thread-scoped instant: renders as a tick on its track.
            fields.push(("s", Json::str_of("t")));
        }
    }
    if e.arg >= 0 {
        fields.push(("args", Json::obj_from(vec![("v", Json::int(e.arg))])));
    }
    Json::obj_from(fields)
}

/// Build the trace document from explicit events + thread names.
/// Threads that recorded events but never registered a name get a
/// generated `thread-<tid>` track name.
pub fn chrome_trace(events: &[SpanEvent], names: &[(u64, String)], dropped: u64) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + names.len() + 1);
    let mut named: Vec<u64> = names.iter().map(|(t, _)| *t).collect();
    for (tid, name) in names {
        out.push(meta_thread_name(*tid, name));
    }
    for e in events {
        if !named.contains(&e.tid) {
            named.push(e.tid);
            out.push(meta_thread_name(e.tid, &format!("thread-{}", e.tid)));
        }
        out.push(trace_event(e));
    }
    let mut top = vec![
        ("displayTimeUnit", Json::str_of("ms")),
        ("traceEvents", Json::Arr(out)),
    ];
    if dropped > 0 {
        top.push(("droppedEvents", Json::int(dropped as i64)));
    }
    Json::obj_from(top)
}

/// Drain the global recorder and write a Chrome trace to `path`.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let (events, names) = span::take_events();
    let doc = chrome_trace(&events, &names, span::dropped_events());
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing chrome trace to {}", path.display()))?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cat: &'static str, name: &'static str, kind: EventKind, tid: u64) -> SpanEvent {
        SpanEvent {
            cat,
            name,
            kind,
            tid,
            t0_us: 10,
            dur_us: 5,
            arg: if name == "dispatch" { 2 } else { -1 },
        }
    }

    fn field<'a>(e: &'a Json, key: &str) -> &'a str {
        e.opt(key).and_then(|v| v.str().ok()).unwrap_or("")
    }

    #[test]
    fn trace_document_round_trips_through_the_json_parser() {
        let events = vec![
            ev("serve", "dispatch", EventKind::Complete, 0),
            ev("serve", "breaker_open", EventKind::Instant, 0),
            ev("kernel", "conv", EventKind::Complete, 3),
        ];
        let names = vec![(3u64, "steal-worker-0".to_string())];
        let doc = chrome_trace(&events, &names, 0);
        let parsed = Json::parse(&doc.to_string()).expect("trace is valid JSON");
        assert_eq!(parsed.get("displayTimeUnit").unwrap().str().unwrap(), "ms");
        let evs = parsed.get("traceEvents").unwrap().arr().unwrap();
        // 3 events + metadata for tids {3 (named), 0 (generated)}.
        assert_eq!(evs.len(), 5);

        let dispatch = evs.iter().find(|e| field(e, "name") == "dispatch").unwrap();
        assert_eq!(field(dispatch, "ph"), "X");
        assert_eq!(field(dispatch, "cat"), "serve");
        assert_eq!(dispatch.get("ts").unwrap().usize().unwrap(), 10);
        assert_eq!(dispatch.get("dur").unwrap().usize().unwrap(), 5);
        assert_eq!(dispatch.get("args").unwrap().get("v").unwrap().usize().unwrap(), 2);

        let instant = evs
            .iter()
            .find(|e| field(e, "name") == "breaker_open")
            .unwrap();
        assert_eq!(field(instant, "ph"), "i");
        assert_eq!(field(instant, "s"), "t");

        let metas: Vec<_> = evs.iter().filter(|e| field(e, "ph") == "M").collect();
        assert_eq!(metas.len(), 2);
        assert!(metas.iter().any(|m| m
            .get("args")
            .unwrap()
            .get("name")
            .unwrap()
            .str()
            .unwrap()
            == "steal-worker-0"));
    }

    #[test]
    fn dropped_events_are_surfaced() {
        let doc = chrome_trace(&[], &[], 12);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("droppedEvents").unwrap().usize().unwrap(), 12);
        let doc = chrome_trace(&[], &[], 0);
        assert!(Json::parse(&doc.to_string()).unwrap().opt("droppedEvents").is_none());
    }
}
