//! Process-wide metrics: named counters, gauges, and log-bucketed
//! histograms with a JSON snapshot and a Prometheus-style text
//! exposition.
//!
//! Counters and gauges are plain name → value maps behind one mutex;
//! recording is a lock + BTreeMap probe, which is cheap at the event
//! granularity they are used for (sheds, retries, breaker trips — not
//! per-element kernel work).  Histograms are log-bucketed: bucket
//! boundaries grow geometrically by [`GROWTH`] so a single `record` is
//! O(1) (one `ln`, one index increment) and any reported quantile is
//! within ~1% relative error of the exact order statistic.  That bound
//! is pinned by tests in `serve/stats.rs` against the exact sorted-vec
//! percentile on seeded traces.
//!
//! A registry is an ordinary value — `serve` attaches a fresh one per
//! scheduler run so tests never share counters — while deep layers
//! that cannot thread a handle (the planner's memo tables) record into
//! [`Registry::global`].

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

use crate::util::json::Json;

/// Geometric growth factor between histogram bucket boundaries.  With
/// 2% growth the geometric midpoint of a bucket is at most ~1% away
/// (relative) from any sample that landed in it.
const GROWTH: f64 = 1.02;
/// Lower edge of bucket 1.  Samples at or below this land in bucket 0
/// and are reported as `HIST_MIN` (clamped to the exact observed min).
const HIST_MIN: f64 = 1e-6;
/// Bucket count: enough to cover `HIST_MIN * GROWTH^n` up to ~1e7,
/// i.e. nanoseconds through hours when samples are milliseconds.
const N_BUCKETS: usize = 1520;

fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Fixed-layout logarithmic histogram: O(1) record, ~1% relative
/// error on quantiles, no allocation after the first record.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// Lazily allocated on first record so an empty histogram is tiny.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Non-finite samples are counted here and excluded from quantiles.
    non_finite: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            non_finite: 0,
        }
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Bucket index for a finite sample.  0 holds everything at or
    /// below `HIST_MIN` (including zeros and negatives); the last
    /// bucket holds the overflow tail.
    fn bucket_of(v: f64) -> usize {
        if v <= HIST_MIN {
            return 0;
        }
        let i = ((v / HIST_MIN).ln() / GROWTH.ln()).floor() as usize + 1;
        i.min(N_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the representative value a
    /// quantile query reports for samples that landed there.
    fn representative(i: usize) -> f64 {
        if i == 0 {
            return HIST_MIN;
        }
        HIST_MIN * GROWTH.powf(i as f64 - 0.5)
    }

    /// O(1): one logarithm and one slot increment.  Non-finite
    /// samples are tallied separately and never enter the quantiles.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            self.non_finite += 1;
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0u64; N_BUCKETS];
        }
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn non_finite(&self) -> u64 {
        self.non_finite
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Quantile `p` in [0, 1].  Walks the cumulative counts to the
    /// bucket holding rank `p * (count - 1)` and reports its geometric
    /// midpoint, clamped into the exact observed [min, max] so p0 and
    /// p100 are exact.  Empty histogram reports 0.0.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min;
        }
        if p >= 1.0 {
            return self.max;
        }
        let target = p * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum as f64 > target {
                return Self::representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    fn summary_json(&self) -> Json {
        Json::obj_from(vec![
            ("count", Json::int(self.count as i64)),
            ("non_finite", Json::int(self.non_finite as i64)),
            ("sum", Json::num(self.sum)),
            ("min", Json::num(self.min())),
            ("max", Json::num(self.max())),
            ("mean", Json::num(self.mean())),
            ("p50", Json::num(self.percentile(0.50))),
            ("p95", Json::num(self.percentile(0.95))),
            ("p99", Json::num(self.percentile(0.99))),
        ])
    }
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LogHistogram>,
}

/// Registry of named counters, gauges, and histograms.  Interior
/// mutability: every method takes `&self`, so a registry can be shared
/// across the scheduler and its helpers without threading `&mut`.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry for layers that cannot carry a
    /// handle (planner memo tables, DP builds).  Serve runs attach
    /// their own per-run registry instead, so test runs never share
    /// request counters through this.
    pub fn global() -> &'static Registry {
        static G: OnceLock<Registry> = OnceLock::new();
        G.get_or_init(Registry::new)
    }

    pub fn counter_add(&self, name: &str, delta: u64) {
        let mut g = lock_recover(&self.inner);
        match g.counters.get_mut(name) {
            Some(v) => *v += delta,
            None => {
                g.counters.insert(name.to_string(), delta);
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        lock_recover(&self.inner).counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&self, name: &str, v: f64) {
        lock_recover(&self.inner).gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        lock_recover(&self.inner).gauges.get(name).copied()
    }

    /// Record one sample into the named histogram (created on first
    /// use).  O(1) past the name probe.
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = lock_recover(&self.inner);
        match g.hists.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = LogHistogram::new();
                h.record(v);
                g.hists.insert(name.to_string(), h);
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Option<LogHistogram> {
        lock_recover(&self.inner).hists.get(name).cloned()
    }

    /// Drop every metric.  Test hook; also used when a long-lived
    /// process wants a fresh window.
    pub fn reset(&self) {
        let mut g = lock_recover(&self.inner);
        g.counters.clear();
        g.gauges.clear();
        g.hists.clear();
    }

    /// Full snapshot as `{"counters": {...}, "gauges": {...},
    /// "histograms": {name: {count, sum, min, max, mean, p50, p95,
    /// p99}}}` — the shape `serve --metrics` writes.
    pub fn snapshot_json(&self) -> Json {
        let g = lock_recover(&self.inner);
        let counters = g
            .counters
            .iter()
            .map(|(k, v)| (k.as_str(), Json::int(*v as i64)))
            .collect::<Vec<_>>();
        let gauges = g
            .gauges
            .iter()
            .map(|(k, v)| (k.as_str(), Json::num(*v)))
            .collect::<Vec<_>>();
        let hists = g
            .hists
            .iter()
            .map(|(k, h)| (k.as_str(), h.summary_json()))
            .collect::<Vec<_>>();
        Json::obj_from(vec![
            ("counters", Json::obj_from(counters)),
            ("gauges", Json::obj_from(gauges)),
            ("histograms", Json::obj_from(hists)),
        ])
    }

    /// Prometheus text exposition: counters as `counter`, gauges as
    /// `gauge`, histograms as `summary` quantile lines plus
    /// `_sum`/`_count`.
    pub fn render_prometheus(&self) -> String {
        let g = lock_recover(&self.inner);
        let mut out = String::new();
        for (k, v) in &g.counters {
            out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in &g.gauges {
            out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, h) in &g.hists {
            out.push_str(&format!("# TYPE {k} summary\n"));
            for (q, p) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                out.push_str(&format!("{k}{{quantile=\"{p}\"}} {}\n", h.percentile(q)));
            }
            out.push_str(&format!("{k}_sum {}\n{k}_count {}\n", h.sum(), h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let r = Registry::new();
        r.counter_add("requests_offered", 3);
        r.counter_add("requests_offered", 2);
        r.gauge_set("active_plan", 1.0);
        r.gauge_set("active_plan", 2.0);
        assert_eq!(r.counter("requests_offered"), 5);
        assert_eq!(r.counter("absent"), 0);
        assert_eq!(r.gauge("active_plan"), Some(2.0));
        assert_eq!(r.gauge("absent"), None);
    }

    #[test]
    fn histogram_quantiles_stay_within_relative_error() {
        let mut h = LogHistogram::new();
        // 1..=1000 ms: exact p-th percentile of 1..=n is ~p*n.
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        for (p, exact) in [(0.5, 500.5), (0.95, 950.05), (0.99, 990.01)] {
            let got = h.percentile(p);
            let rel = (got - exact).abs() / exact;
            assert!(rel < 0.02, "p{p}: got {got}, exact {exact}, rel {rel}");
        }
        // p0/p100 exact by clamping.
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(1.0), 1000.0);
    }

    #[test]
    fn histogram_edge_cases() {
        let h = LogHistogram::new();
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);

        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(0.0); // at-or-below HIST_MIN → bucket 0, reported as min
        h.record(5.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.non_finite(), 2);
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(1.0), 5.0);
    }

    #[test]
    fn single_sample_is_exact_at_every_quantile() {
        let mut h = LogHistogram::new();
        h.record(3.25);
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile(p), 3.25);
        }
    }

    #[test]
    fn snapshot_and_prometheus_expose_all_kinds() {
        let r = Registry::new();
        r.counter_add("shed_total", 7);
        r.gauge_set("queue_depth", 4.0);
        r.observe("latency_ms", 2.0);
        r.observe("latency_ms", 4.0);
        let js = r.snapshot_json();
        assert_eq!(js.get("counters").unwrap().get("shed_total").unwrap().usize().unwrap(), 7);
        assert_eq!(js.get("gauges").unwrap().get("queue_depth").unwrap().f64().unwrap(), 4.0);
        let h = js.get("histograms").unwrap().get("latency_ms").unwrap();
        assert_eq!(h.get("count").unwrap().usize().unwrap(), 2);
        assert!(h.get("p50").unwrap().f64().unwrap() > 0.0);

        let text = r.render_prometheus();
        assert!(text.contains("# TYPE shed_total counter"));
        assert!(text.contains("shed_total 7"));
        assert!(text.contains("# TYPE queue_depth gauge"));
        assert!(text.contains("# TYPE latency_ms summary"));
        assert!(text.contains("latency_ms_count 2"));
        assert!(text.contains("latency_ms{quantile=\"0.95\"}"));
    }

    #[test]
    fn reset_clears_everything() {
        let r = Registry::new();
        r.counter_add("a", 1);
        r.observe("h", 1.0);
        r.reset();
        assert_eq!(r.counter("a"), 0);
        assert!(r.histogram("h").is_none());
    }
}
