//! Request-lifecycle plumbing: a [`ReqTrace`] rides alongside each
//! queued request in the scheduler and stamps out one `req`-category
//! span per lifecycle stage — admission → queue wait → dispatch →
//! reply — plus instant events for sheds and retries, so every
//! rejected or retried request is visible on the trace, not just the
//! aggregate counters.
//!
//! A `ReqTrace` is two words (a stage-start [`Instant`] and an active
//! flag latched from the obs level at creation); when recording is
//! off every method is a single branch, so the scheduler carries them
//! unconditionally.

use std::time::Instant;

use crate::obs::span;

/// Category all request-lifecycle events are filed under.
pub const CAT: &str = "req";

/// Per-request stage tracker.  Created when the request reaches the
/// scheduler; each [`mark`](ReqTrace::mark) closes the stage that
/// began at the previous mark (or at creation) and starts the next.
#[derive(Debug)]
pub struct ReqTrace {
    active: bool,
    t_mark: Instant,
}

impl ReqTrace {
    /// Latch the obs level: a trace created while recording is off
    /// stays silent for its whole life (cheap and unambiguous even if
    /// the level flips mid-request).
    pub fn start() -> ReqTrace {
        ReqTrace {
            active: span::enabled(),
            t_mark: Instant::now(),
        }
    }

    /// Close the current stage as a span named `stage` spanning
    /// [previous mark, now), then start the next stage at now.
    pub fn mark(&mut self, stage: &'static str) {
        if !self.active {
            return;
        }
        let now = Instant::now();
        span::event_between(CAT, stage, self.t_mark, now, -1);
        self.t_mark = now;
    }

    /// Record a point event on the request's lifecycle (shed reason,
    /// retry) without closing the running stage.
    pub fn instant(&self, name: &'static str, arg: i64) {
        if !self.active {
            return;
        }
        span::instant(CAT, name, arg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{set_level, take_events, test_lock, EventKind, ObsLevel};

    #[test]
    fn marks_emit_contiguous_stages() {
        let _l = test_lock();
        set_level(ObsLevel::Spans);
        let _ = take_events();
        let mut tr = ReqTrace::start();
        std::thread::sleep(std::time::Duration::from_millis(1));
        tr.mark("admission");
        std::thread::sleep(std::time::Duration::from_millis(1));
        tr.mark("queue");
        tr.instant("shed_deadline", 0);
        set_level(ObsLevel::Off);
        let (events, _) = take_events();
        let adm = events.iter().find(|e| e.name == "admission").expect("admission span");
        let q = events.iter().find(|e| e.name == "queue").expect("queue span");
        assert_eq!(adm.cat, CAT);
        assert_eq!(adm.kind, EventKind::Complete);
        // Stages are contiguous: queue starts where admission ended.
        assert_eq!(adm.t0_us + adm.dur_us, q.t0_us);
        assert!(events
            .iter()
            .any(|e| e.name == "shed_deadline" && e.kind == EventKind::Instant));
    }

    #[test]
    fn inactive_trace_is_silent_even_if_level_rises_later() {
        let _l = test_lock();
        set_level(ObsLevel::Off);
        let mut tr = ReqTrace::start();
        set_level(ObsLevel::Spans);
        let _ = take_events();
        tr.mark("admission");
        tr.instant("retry", 1);
        set_level(ObsLevel::Off);
        assert!(take_events().0.is_empty());
    }
}
