//! Lightweight span recorder: RAII guards over [`Instant`], buffered
//! per thread and flushed into one shared sink.
//!
//! The whole module is gated on a single process-wide [`ObsLevel`]
//! loaded with one relaxed atomic read.  At `Off` (the default, and
//! the state every test runs under unless it opts in) a span guard is
//! two plain fields and a clock read — no allocation, no lock, no
//! buffer touch — so the exact-tier byte-identity and deterministic
//! pool-schedule contracts are untouched: spans observe timing, they
//! never touch tensor data or task order.  `Spans` records the serve
//! lifecycle (request stages, dispatch waves, breaker/switch events);
//! `Full` additionally records per-layer kernel spans and per-task
//! pool spans.
//!
//! Category taxonomy (the `cat` field, fixed `&'static str`s):
//!
//! | cat      | emitted by                                            |
//! |----------|-------------------------------------------------------|
//! | `req`    | per-request lifecycle stages (`obs::timeline`)        |
//! | `serve`  | scheduler waves, retries, breaker + switch instants   |
//! | `exec`   | whole-forward execution (`MultiPlanEngine`)           |
//! | `kernel` | per-layer kernel work (`HostExec`, level `Full`)      |
//! | `pool`   | per-task steal-pool work (level `Full`)               |
//! | `fault`  | injected chaos delays — never attributed to `exec`    |
//! | `plan`   | planner table builds / frontier extracts              |
//!
//! Timestamps are microseconds since the recorder epoch (first event
//! or first `set_level` call), matching the Chrome trace-event `ts`
//! unit so `obs::trace_export` can write them out unmodified.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use anyhow::{bail, Result};

/// How much the recorder captures.  One process-wide atomic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ObsLevel {
    /// Record nothing (default).
    Off = 0,
    /// Request lifecycle + scheduler + fault spans.
    Spans = 1,
    /// Everything, including per-layer kernel and per-task pool spans.
    Full = 2,
}

impl ObsLevel {
    pub fn parse(s: &str) -> Result<ObsLevel> {
        match s {
            "off" => Ok(ObsLevel::Off),
            "spans" => Ok(ObsLevel::Spans),
            "full" => Ok(ObsLevel::Full),
            other => bail!("unknown obs level '{other}' (expected off|spans|full)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Spans => "spans",
            ObsLevel::Full => "full",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(0);

pub fn set_level(l: ObsLevel) {
    // Pin the epoch no later than enabling, so no recorded Instant
    // can precede it (saturating subtraction guards stragglers).
    if l != ObsLevel::Off {
        let _ = sink();
    }
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> ObsLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => ObsLevel::Off,
        1 => ObsLevel::Spans,
        _ => ObsLevel::Full,
    }
}

/// True at `Spans` or `Full` — the one branch every disabled call pays.
#[inline]
pub fn enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) >= ObsLevel::Spans as u8
}

/// True only at `Full` (per-layer kernel / per-task pool spans).
#[inline]
pub fn is_full() -> bool {
    LEVEL.load(Ordering::Relaxed) >= ObsLevel::Full as u8
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A duration (`ph: "X"` in the Chrome trace).
    Complete,
    /// A point event (`ph: "i"`).
    Instant,
}

/// One recorded event.  `name`/`cat` are `&'static str` so recording
/// never allocates; `arg` is a free-form numeric payload (plan index,
/// layer index, attempt number; -1 = none).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub cat: &'static str,
    pub name: &'static str,
    pub kind: EventKind,
    pub tid: u64,
    /// Microseconds since the recorder epoch.
    pub t0_us: u64,
    pub dur_us: u64,
    pub arg: i64,
}

/// Shared sink: the epoch plus everything flushed out of per-thread
/// buffers.  Capped so a forgotten `--trace` on a long run cannot eat
/// unbounded memory; overflow is counted, not silently dropped.
const SINK_CAP: usize = 1 << 20;
/// Per-thread buffer length that triggers a flush into the sink.
const FLUSH_AT: usize = 256;

struct Sink {
    epoch: Instant,
    events: Mutex<Vec<SpanEvent>>,
    names: Mutex<Vec<(u64, String)>>,
    dropped: AtomicU64,
}

fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| Sink {
        epoch: Instant::now(),
        events: Mutex::new(Vec::new()),
        names: Mutex::new(Vec::new()),
        dropped: AtomicU64::new(0),
    })
}

/// Microseconds since the recorder epoch (0 for pre-epoch instants).
pub fn micros_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(sink().epoch).as_micros() as u64
}

static NEXT_TID: AtomicU64 = AtomicU64::new(0);

struct ThreadBuf {
    tid: u64,
    buf: Vec<SpanEvent>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let s = sink();
        let mut ev = lock_recover(&s.events);
        let room = SINK_CAP.saturating_sub(ev.len());
        if room < self.buf.len() {
            s.dropped
                .fetch_add((self.buf.len() - room) as u64, Ordering::Relaxed);
        }
        ev.extend(self.buf.drain(..).take(room));
        self.buf.clear();
    }
}

impl Drop for ThreadBuf {
    // Scoped pool workers exit at scope end, so their remaining
    // events land in the sink before the dispatching wave returns.
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: Vec::new(),
    });
}

fn push(ev: SpanEvent) {
    TLS.with(|b| {
        let mut b = b.borrow_mut();
        b.buf.push(ev);
        if b.buf.len() >= FLUSH_AT {
            b.flush();
        }
    });
}

fn current_tid() -> u64 {
    TLS.with(|b| b.borrow().tid)
}

/// Name the calling thread in trace exports ("steal-worker-3", ...).
/// No-op when recording is off.
pub fn register_thread(name: &str) {
    if !enabled() {
        return;
    }
    let tid = current_tid();
    lock_recover(&sink().names).push((tid, name.to_string()));
}

/// Name a pool worker (`<prefix>-<idx>`) in trace exports, without
/// paying the format when recording is off.
pub fn register_worker(prefix: &str, idx: usize) {
    if !enabled() {
        return;
    }
    register_thread(&format!("{prefix}-{idx}"));
}

/// RAII span: records a `Complete` event over its lifetime when
/// `active`.  Construct via [`span`], [`span_arg`], or
/// [`span_full_arg`]; inactive guards do nothing on drop.
pub struct SpanGuard {
    cat: &'static str,
    name: &'static str,
    arg: i64,
    start: Instant,
    active: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let t0 = micros_since_epoch(self.start);
        let t1 = micros_since_epoch(Instant::now());
        push(SpanEvent {
            cat: self.cat,
            name: self.name,
            kind: EventKind::Complete,
            tid: current_tid(),
            t0_us: t0,
            dur_us: t1.saturating_sub(t0),
            arg: self.arg,
        });
    }
}

pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    span_arg(cat, name, -1)
}

pub fn span_arg(cat: &'static str, name: &'static str, arg: i64) -> SpanGuard {
    SpanGuard {
        cat,
        name,
        arg,
        start: Instant::now(),
        active: enabled(),
    }
}

/// Span active only at [`ObsLevel::Full`] (per-layer kernels,
/// per-task pool work).
pub fn span_full_arg(cat: &'static str, name: &'static str, arg: i64) -> SpanGuard {
    SpanGuard {
        cat,
        name,
        arg,
        start: Instant::now(),
        active: is_full(),
    }
}

/// Record a point event (breaker trip, plan switch, shed, retry).
pub fn instant(cat: &'static str, name: &'static str, arg: i64) {
    if !enabled() {
        return;
    }
    push(SpanEvent {
        cat,
        name,
        kind: EventKind::Instant,
        tid: current_tid(),
        t0_us: micros_since_epoch(Instant::now()),
        dur_us: 0,
        arg,
    });
}

/// Record a `Complete` event over an explicit interval — used by
/// `obs::timeline` to close a stage retroactively.
pub fn event_between(
    cat: &'static str,
    name: &'static str,
    start: Instant,
    end: Instant,
    arg: i64,
) {
    if !enabled() {
        return;
    }
    push(SpanEvent {
        cat,
        name,
        kind: EventKind::Complete,
        tid: current_tid(),
        t0_us: micros_since_epoch(start),
        dur_us: end.saturating_duration_since(start).as_micros() as u64,
        arg,
    });
}

/// Drain the sink: the calling thread's buffer is flushed first, then
/// every event and thread-name registration accumulated so far is
/// moved out.  Buffers of *live* other threads flush on their next
/// 256th event or at thread exit — the serve CLI drains after the
/// scheduler (and every scoped worker) has returned.
pub fn take_events() -> (Vec<SpanEvent>, Vec<(u64, String)>) {
    TLS.with(|b| b.borrow_mut().flush());
    let s = sink();
    let events = std::mem::take(&mut *lock_recover(&s.events));
    let names = std::mem::take(&mut *lock_recover(&s.names));
    (events, names)
}

/// Events lost to the sink cap since process start.
pub fn dropped_events() -> u64 {
    sink().dropped.load(Ordering::Relaxed)
}

/// Serializes tests (and benches) that mutate the process-wide level
/// or drain the shared sink.  Not for production use.
#[doc(hidden)]
pub fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `obs_span!(cat, name)` / `obs_span!(cat, name, arg)` — drop an
/// RAII span guard into the current scope.
#[macro_export]
macro_rules! obs_span {
    ($cat:expr, $name:expr) => {
        let _obs_span_guard = $crate::obs::span::span($cat, $name);
    };
    ($cat:expr, $name:expr, $arg:expr) => {
        let _obs_span_guard = $crate::obs::span::span_arg($cat, $name, $arg);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_guard_records_nothing() {
        let _l = test_lock();
        set_level(ObsLevel::Off);
        let before = take_events().0.len();
        {
            let _g = span("serve", "dispatch");
            instant("serve", "plan_switch", 1);
        }
        assert_eq!(take_events().0.len(), 0, "off level must record nothing");
        let _ = before;
    }

    #[test]
    fn guard_records_complete_event_with_duration() {
        let _l = test_lock();
        set_level(ObsLevel::Spans);
        let _ = take_events();
        {
            let _g = span_arg("serve", "dispatch", 7);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        instant("serve", "breaker_open", 0);
        set_level(ObsLevel::Off);
        let (events, _) = take_events();
        let d = events
            .iter()
            .find(|e| e.name == "dispatch" && e.cat == "serve")
            .expect("dispatch span recorded");
        assert_eq!(d.kind, EventKind::Complete);
        assert_eq!(d.arg, 7);
        assert!(d.dur_us >= 1_000, "2ms sleep must show up, got {}us", d.dur_us);
        assert!(events
            .iter()
            .any(|e| e.name == "breaker_open" && e.kind == EventKind::Instant));
    }

    #[test]
    fn full_only_spans_respect_level() {
        let _l = test_lock();
        set_level(ObsLevel::Spans);
        let _ = take_events();
        {
            let _g = span_full_arg("kernel", "conv", 0);
        }
        assert!(
            !take_events().0.iter().any(|e| e.cat == "kernel"),
            "full-only span must not record at spans level"
        );
        set_level(ObsLevel::Full);
        {
            let _g = span_full_arg("kernel", "conv", 3);
        }
        set_level(ObsLevel::Off);
        let (events, _) = take_events();
        let k = events.iter().find(|e| e.cat == "kernel").expect("kernel span");
        assert_eq!(k.arg, 3);
    }

    #[test]
    fn worker_threads_flush_on_exit_with_registered_names() {
        let _l = test_lock();
        set_level(ObsLevel::Spans);
        let _ = take_events();
        std::thread::scope(|s| {
            s.spawn(|| {
                register_thread("test-worker");
                let _g = span("pool", "task");
            });
        });
        set_level(ObsLevel::Off);
        let (events, names) = take_events();
        let t = events.iter().find(|e| e.name == "task").expect("worker span flushed");
        assert!(names.iter().any(|(tid, n)| *tid == t.tid && n == "test-worker"));
    }

    #[test]
    fn obs_level_parse_round_trips() {
        for l in [ObsLevel::Off, ObsLevel::Spans, ObsLevel::Full] {
            assert_eq!(ObsLevel::parse(l.name()).unwrap(), l);
        }
        assert!(ObsLevel::parse("verbose").is_err());
    }
}
