//! Batch assembly: shuffled train batches and sequential val batches as
//! host tensors ready for the AOT train/eval artifacts.

use crate::data::synth::{random_erase, sample_into, Split, SynthSpec};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct Batcher {
    pub spec: SynthSpec,
    pub batch: usize,
    pub augment: bool,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    epoch: usize,
}

impl Batcher {
    pub fn new(spec: SynthSpec, batch: usize, seed: u64, augment: bool) -> Batcher {
        let order: Vec<usize> = (0..spec.train_len()).collect();
        let mut b = Batcher { spec, batch, augment, order, cursor: 0, rng: Rng::new(seed), epoch: 0 };
        b.shuffle();
        b
    }

    fn shuffle(&mut self) {
        // Fisher-Yates
        for i in (1..self.order.len()).rev() {
            let j = self.rng.below(i + 1);
            self.order.swap(i, j);
        }
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// steps per epoch
    pub fn steps_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Next shuffled train batch: (x [B,3,H,W], y [B]).
    pub fn next_train(&mut self) -> (Tensor, Tensor) {
        let hw = self.spec.hw;
        let mut x = Tensor::zeros(&[self.batch, 3, hw, hw]);
        let mut y = Tensor::zeros(&[self.batch]);
        let stride = 3 * hw * hw;
        for b in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epoch += 1;
                self.shuffle();
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            let label = sample_into(
                &self.spec,
                Split::Train,
                idx,
                &mut x.data[b * stride..(b + 1) * stride],
            );
            y.data[b] = label as f32;
        }
        if self.augment {
            random_erase(&mut x, &mut self.rng, 0.25);
        }
        (x, y)
    }

    /// Val batch `n` (sequential, deterministic); final partial batches
    /// are padded and the pad rows get label = num_classes, which
    /// one-hots to a zero row in the eval graph — they contribute
    /// nothing to loss_sum or ncorrect.  `valid` is the real count.
    pub fn val_batch(&self, n: usize, batch: usize) -> (Tensor, Tensor, usize) {
        let hw = self.spec.hw;
        let total = self.spec.val_len();
        let start = n * batch;
        let valid = batch.min(total.saturating_sub(start));
        let mut x = Tensor::zeros(&[batch, 3, hw, hw]);
        let mut y = Tensor::zeros(&[batch]);
        let stride = 3 * hw * hw;
        for b in 0..batch {
            if b < valid {
                let label = sample_into(
                    &self.spec,
                    Split::Val,
                    start + b,
                    &mut x.data[b * stride..(b + 1) * stride],
                );
                y.data[b] = label as f32;
            } else {
                y.data[b] = self.spec.num_classes as f32; // pad sentinel
            }
        }
        (x, y, valid)
    }

    pub fn val_batches(&self, batch: usize) -> usize {
        self.spec.val_len().div_ceil(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_shapes() {
        let spec = SynthSpec::quickstart(8);
        let mut b = Batcher::new(spec, 16, 1, false);
        let (x, y) = b.next_train();
        assert_eq!(x.shape, vec![16, 3, 8, 8]);
        assert_eq!(y.shape, vec![16]);
        assert!(y.data.iter().all(|&l| l >= 0.0 && l < 10.0));
    }

    #[test]
    fn epoch_wraps_and_reshuffles() {
        let spec = SynthSpec::quickstart(8); // 640 train samples
        let mut b = Batcher::new(spec, 64, 2, false);
        assert_eq!(b.steps_per_epoch(), 10);
        for _ in 0..10 {
            b.next_train();
        }
        assert_eq!(b.epoch(), 0);
        b.next_train();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn val_batches_cover_everything_once() {
        let spec = SynthSpec::quickstart(8); // 320 val
        let b = Batcher::new(spec.clone(), 16, 3, false);
        let nb = b.val_batches(128);
        assert_eq!(nb, 3);
        let (_, _, v0) = b.val_batch(0, 128);
        let (_, _, v2) = b.val_batch(2, 128);
        assert_eq!(v0, 128);
        assert_eq!(v2, 320 - 256);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SynthSpec::quickstart(8);
        let mut a = Batcher::new(spec.clone(), 8, 7, false);
        let mut b = Batcher::new(spec, 8, 7, false);
        let (xa, ya) = a.next_train();
        let (xb, yb) = b.next_train();
        assert_eq!(xa.data, xb.data);
        assert_eq!(ya.data, yb.data);
    }
}
