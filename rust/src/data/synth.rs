//! SynthCIFAR: a deterministic, procedurally-generated classification
//! dataset (the ImageNet-100 / ImageNet substitute — DESIGN.md §2).
//!
//! Each class is a signature mixture of (a) an oriented sinusoidal
//! grating, (b) a Gaussian blob at a class-specific position, and (c) a
//! class color balance; each *sample* adds phase jitter, position
//! jitter, and pixel noise.  Images are generated on the fly from
//! (dataset_seed, index) — no storage, perfectly reproducible, and the
//! class structure is learnable by a small CNN while degrading under
//! activation removal exactly like a natural-image task (what the
//! importance stage needs).

use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub num_classes: usize,
    pub hw: usize,
    pub seed: u64,
    /// samples per class in the train split
    pub train_per_class: usize,
    /// samples per class in the val split
    pub val_per_class: usize,
    pub noise: f32,
}

impl SynthSpec {
    pub fn imagenet100_analog(hw: usize) -> SynthSpec {
        // noise level tuned so the vanilla MBV2-micro lands in the
        // 80-90% band after the standard pretrain budget — leaving the
        // headroom that makes compression accuracy comparisons
        // meaningful (a saturated task would rank all methods equal)
        SynthSpec {
            num_classes: 100,
            hw,
            seed: 0xC1FA8,
            train_per_class: 160,
            val_per_class: 32,
            noise: 0.75,
        }
    }

    pub fn quickstart(hw: usize) -> SynthSpec {
        SynthSpec {
            num_classes: 10,
            hw,
            seed: 0xC1FA9,
            train_per_class: 64,
            val_per_class: 32,
            noise: 1.0,
        }
    }

    pub fn train_len(&self) -> usize {
        self.num_classes * self.train_per_class
    }

    pub fn val_len(&self) -> usize {
        self.num_classes * self.val_per_class
    }
}

/// Class-level generative parameters (derived, not stored).
struct ClassSig {
    fx: f32,
    fy: f32,
    orient: f32,
    blob_x: f32,
    blob_y: f32,
    blob_r: f32,
    color: [f32; 3],
    stripe_color: [f32; 3],
}

fn class_sig(spec: &SynthSpec, class: usize) -> ClassSig {
    let mut r = Rng::new(spec.seed ^ (class as u64).wrapping_mul(0x9E3779B97F4A7C15));
    ClassSig {
        fx: 1.0 + r.uniform() * 5.0,
        fy: 1.0 + r.uniform() * 5.0,
        orient: r.uniform() * std::f32::consts::PI,
        blob_x: 0.2 + 0.6 * r.uniform(),
        blob_y: 0.2 + 0.6 * r.uniform(),
        blob_r: 0.08 + 0.18 * r.uniform(),
        color: [r.range(-1.0, 1.0), r.range(-1.0, 1.0), r.range(-1.0, 1.0)],
        stripe_color: [r.range(-1.0, 1.0), r.range(-1.0, 1.0), r.range(-1.0, 1.0)],
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
}

/// Generate sample `index` of `split` into a CHW f32 buffer; returns label.
pub fn sample_into(spec: &SynthSpec, split: Split, index: usize, out: &mut [f32]) -> usize {
    let hw = spec.hw;
    assert_eq!(out.len(), 3 * hw * hw);
    let per = match split {
        Split::Train => spec.train_per_class,
        Split::Val => spec.val_per_class,
    };
    let class = index / per % spec.num_classes;
    let tag = match split {
        Split::Train => 0x7124u64,
        Split::Val => 0x8a31u64,
    };
    let mut r = Rng::new(spec.seed ^ tag ^ (index as u64).wrapping_mul(0xD1B54A32D192ED03));
    let sig = class_sig(spec, class);
    // per-sample jitter
    let phase = r.uniform() * 2.0 * std::f32::consts::PI;
    let dx = r.range(-0.08, 0.08);
    let dy = r.range(-0.08, 0.08);
    let (sin_o, cos_o) = sig.orient.sin_cos();
    let tau = 2.0 * std::f32::consts::PI;
    for y in 0..hw {
        for x in 0..hw {
            let u = x as f32 / hw as f32;
            let v = y as f32 / hw as f32;
            let ur = cos_o * u - sin_o * v;
            let vr = sin_o * u + cos_o * v;
            let grating = (tau * (sig.fx * ur + sig.fy * vr) + phase).sin();
            let bx = u - (sig.blob_x + dx);
            let by = v - (sig.blob_y + dy);
            let blob = (-(bx * bx + by * by) / (2.0 * sig.blob_r * sig.blob_r)).exp();
            for c in 0..3 {
                let val = 0.55 * grating * sig.stripe_color[c]
                    + 1.0 * blob * sig.color[c]
                    + spec.noise * r.normal();
                out[c * hw * hw + y * hw + x] = val;
            }
        }
    }
    class
}

/// Random-erasing augmentation (paper's finetune protocol): zero a
/// random rectangle in each image of a CHW batch, with probability p.
pub fn random_erase(batch: &mut Tensor, rng: &mut Rng, p: f32) {
    assert_eq!(batch.rank(), 4);
    let (n, c, h, w) = (batch.shape[0], batch.shape[1], batch.shape[2], batch.shape[3]);
    for b in 0..n {
        if rng.uniform() > p {
            continue;
        }
        let eh = 1 + rng.below(h / 3 + 1);
        let ew = 1 + rng.below(w / 3 + 1);
        let y0 = rng.below(h - eh + 1);
        let x0 = rng.below(w - ew + 1);
        for ch in 0..c {
            for y in y0..y0 + eh {
                for x in x0..x0 + ew {
                    batch.data[((b * c + ch) * h + y) * w + x] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = SynthSpec::quickstart(16);
        let mut a = vec![0f32; 3 * 256];
        let mut b = vec![0f32; 3 * 256];
        let la = sample_into(&spec, Split::Train, 37, &mut a);
        let lb = sample_into(&spec, Split::Train, 37, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn labels_cycle_through_classes() {
        let spec = SynthSpec::quickstart(8);
        let mut buf = vec![0f32; 3 * 64];
        let per = spec.train_per_class;
        assert_eq!(sample_into(&spec, Split::Train, 0, &mut buf), 0);
        assert_eq!(sample_into(&spec, Split::Train, per, &mut buf), 1);
        assert_eq!(
            sample_into(&spec, Split::Train, per * spec.num_classes, &mut buf),
            0
        );
    }

    #[test]
    fn train_and_val_differ() {
        let spec = SynthSpec::quickstart(12);
        let mut a = vec![0f32; 3 * 144];
        let mut b = vec![0f32; 3 * 144];
        sample_into(&spec, Split::Train, 5, &mut a);
        sample_into(&spec, Split::Val, 5, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn class_signal_exceeds_noise() {
        // same-class samples must correlate more than cross-class ones
        // ON AVERAGE (the dataset is deliberately noisy — DESIGN.md §2)
        let spec = SynthSpec::quickstart(16);
        let n = 3 * 256;
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            let dot: f32 = a.iter().zip(b).map(|(p, q)| p * q).sum();
            let na: f32 = a.iter().map(|p| p * p).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|p| p * p).sum::<f32>().sqrt();
            dot / (na * nb)
        };
        let per = spec.train_per_class;
        let (mut same_sum, mut diff_sum) = (0.0f32, 0.0f32);
        let pairs = 12;
        for k in 0..pairs {
            let mut x = vec![0f32; n];
            let mut same = vec![0f32; n];
            let mut diff = vec![0f32; n];
            let class = k % spec.num_classes;
            sample_into(&spec, Split::Train, class * per + k, &mut x);
            sample_into(&spec, Split::Train, class * per + k + 13, &mut same);
            sample_into(
                &spec,
                Split::Train,
                ((class + 1) % spec.num_classes) * per + k,
                &mut diff,
            );
            same_sum += corr(&x, &same);
            diff_sum += corr(&x, &diff);
        }
        assert!(
            same_sum / pairs as f32 > diff_sum / pairs as f32 + 0.002,
            "same {same_sum} vs diff {diff_sum}"
        );
    }

    #[test]
    fn random_erase_zeroes_a_patch() {
        let mut t = Tensor::from_vec(&[1, 1, 8, 8], vec![1.0; 64]).unwrap();
        let mut rng = Rng::new(9);
        random_erase(&mut t, &mut rng, 1.0);
        let zeros = t.data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0 && zeros < 64);
    }
}
