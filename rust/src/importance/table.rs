//! Importance table I[i, j, a, b] — storage, lookup, persistence.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::dp::stage2::NEG_INF;
use crate::model::spec::{ArchConfig, ACT_RELU6};
use crate::util::json::Json;

#[derive(Debug, Clone, Default)]
pub struct ImpTable {
    /// (i, j, a, b) -> accuracy change (already normalized if norm applied)
    entries: BTreeMap<(usize, usize, u8, u8), f64>,
    pub base_acc: f64,
    pub meta: String,
}

impl ImpTable {
    pub fn new(base_acc: f64, meta: &str) -> ImpTable {
        ImpTable { entries: BTreeMap::new(), base_acc, meta: meta.to_string() }
    }

    pub fn insert(&mut self, i: usize, j: usize, a: u8, b: u8, v: f64) {
        self.entries.insert((i, j, a, b), v);
    }

    pub fn get(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        *self.entries.get(&(i, j, a, b)).unwrap_or(&NEG_INF)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&(usize, usize, u8, u8), &f64)> {
        self.entries.iter()
    }

    pub fn values_mut(&mut self) -> impl Iterator<Item = &mut f64> {
        self.entries.values_mut()
    }

    /// Base-space importance I[i, j]: endpoint activations at their
    /// original states (relu6 -> on, id -> off; virtual boundaries on).
    pub fn imp_base(&self, cfg: &ArchConfig, i: usize, j: usize) -> f64 {
        let a = if i == 0 || cfg.spec.layer(i).act == ACT_RELU6 { 1 } else { 0 };
        let b = if j == cfg.spec.l() || cfg.spec.layer(j).act == ACT_RELU6 { 1 } else { 0 };
        self.get(i, j, a, b)
    }

    // -- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj_from(vec![
            ("base_acc", Json::num(self.base_acc)),
            ("meta", Json::str_of(&self.meta)),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|(&(i, j, a, b), &v)| {
                            Json::arr_of([
                                Json::int(i as i64),
                                Json::int(j as i64),
                                Json::int(a as i64),
                                Json::int(b as i64),
                                Json::num(v),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ImpTable> {
        let mut t = ImpTable::new(v.get("base_acc")?.f64()?, v.get("meta")?.str()?);
        for e in v.get("entries")?.arr()? {
            let a = e.arr()?;
            t.insert(
                a[0].usize()?,
                a[1].usize()?,
                a[2].usize()? as u8,
                a[3].usize()? as u8,
                a[4].f64()?,
            );
        }
        Ok(t)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<ImpTable> {
        ImpTable::from_json(&Json::from_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::testutil::tiny_config;

    #[test]
    fn lookup_and_default() {
        let mut t = ImpTable::new(0.8, "test");
        t.insert(1, 4, 1, 0, -0.05);
        assert_eq!(t.get(1, 4, 1, 0), -0.05);
        assert_eq!(t.get(1, 4, 1, 1), NEG_INF);
    }

    #[test]
    fn base_lookup_uses_original_states() {
        let cfg = tiny_config();
        let mut t = ImpTable::new(0.8, "test");
        // block (1,4]: sigma_1 = relu6 -> a=1; sigma_4 = id -> b=0
        t.insert(1, 4, 1, 0, -0.1);
        t.insert(1, 4, 1, 1, -0.2);
        assert_eq!(t.imp_base(&cfg, 1, 4), -0.1);
        // block (0,1]: virtual left boundary -> a=1; sigma_1 relu6 -> b=1
        t.insert(0, 1, 1, 1, -0.3);
        assert_eq!(t.imp_base(&cfg, 0, 1), -0.3);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = ImpTable::new(0.75, "probe_steps=4");
        t.insert(0, 1, 1, 1, -0.01);
        t.insert(1, 4, 1, 0, -0.2);
        let re = ImpTable::from_json(&t.to_json()).unwrap();
        assert_eq!(re.len(), 2);
        assert_eq!(re.get(1, 4, 1, 0), -0.2);
        assert_eq!(re.base_acc, 0.75);
    }
}
