//! Importance normalization (paper Appendix B.3).
//!
//! Short-finetune probes systematically *underestimate* each block's
//! true (train-to-convergence) importance, and the bias compounds with
//! the number of blocks the DP stitches together.  The paper corrects
//! per block with a constant: I <- I - (alpha / |D|) * sum(D), where D
//! is the set of size-one-block accuracy changes after re-init +
//! one-epoch training.

use crate::importance::table::ImpTable;

/// Mean of the size-one-block importance values (the set D).
pub fn d_mean(table: &ImpTable) -> f64 {
    let d: Vec<f64> = table
        .iter()
        .filter(|(&(i, j, _, _), _)| j == i + 1)
        .map(|(_, &v)| v)
        .collect();
    if d.is_empty() {
        0.0
    } else {
        d.iter().sum::<f64>() / d.len() as f64
    }
}

/// Apply the B.3 correction in place; returns the shift applied.
pub fn normalize(table: &mut ImpTable, alpha: f64) -> f64 {
    let shift = alpha * d_mean(table);
    for v in table.values_mut() {
        *v -= shift;
    }
    table.meta = format!("{} | normalized alpha={alpha} shift={shift:.6}", table.meta);
    shift
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_mean_only_uses_singletons() {
        let mut t = ImpTable::new(0.8, "x");
        t.insert(0, 1, 1, 1, -0.02);
        t.insert(1, 2, 1, 1, -0.04);
        t.insert(0, 2, 1, 1, -0.50); // multi-layer: excluded from D
        assert!((d_mean(&t) - -0.03).abs() < 1e-12);
    }

    #[test]
    fn normalize_shifts_every_entry() {
        let mut t = ImpTable::new(0.8, "x");
        t.insert(0, 1, 1, 1, -0.02);
        t.insert(1, 2, 1, 1, -0.04);
        t.insert(0, 2, 1, 1, -0.50);
        let shift = normalize(&mut t, 1.5);
        assert!((shift - 1.5 * -0.03).abs() < 1e-12);
        // subtracting a negative shift raises the values
        assert!((t.get(0, 1, 1, 1) - (-0.02 + 0.045)).abs() < 1e-12);
        assert!((t.get(0, 2, 1, 1) - (-0.50 + 0.045)).abs() < 1e-12);
    }

    #[test]
    fn alpha_zero_is_identity() {
        let mut t = ImpTable::new(0.8, "x");
        t.insert(0, 1, 1, 1, -0.02);
        normalize(&mut t, 0.0);
        assert_eq!(t.get(0, 1, 1, 1), -0.02);
    }
}
