//! Importance evaluation (paper Eq. 3 / Eq. 14 + §5.1): for each probe
//! (i, j, d_i, d_j), deactivate the activations strictly inside the
//! block, set the endpoint states, finetune briefly from the pretrained
//! weight, and record the validation-accuracy change.
//!
//! Every probe runs the SAME train/eval artifacts with a different mask
//! vector (DESIGN.md §5) — zero recompilation, which is what makes the
//! stage embarrassingly parallel in the paper.  Size-one blocks are
//! re-initialized instead (B.3).

use anyhow::Result;

use crate::data::batcher::Batcher;
use crate::importance::table::ImpTable;
use crate::model::spec::{ArchConfig, Probe};
use crate::runtime::engine::Engine;
use crate::runtime::manifest::ArchEntry;
use crate::trainer::eval::eval_masked_subset;
use crate::trainer::params::ParamSet;
use crate::trainer::sgd::{TrainConfig, TrainState, Trainer};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ImportanceConfig {
    /// finetune steps per probe (the paper uses ~1 epoch; we scale down)
    pub steps: usize,
    pub lr: f64,
    /// evaluate on this many val batches (0 = all)
    pub eval_batches: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for ImportanceConfig {
    fn default() -> Self {
        ImportanceConfig { steps: 4, lr: 0.01, eval_batches: 6, seed: 7, verbose: false }
    }
}

/// Build the probe mask: interior activations off, endpoints per (a, b).
pub fn probe_mask(cfg: &ArchConfig, p: &Probe) -> Vec<f32> {
    let mut mask = cfg.spec.default_mask();
    for l in p.i + 1..p.j {
        mask[l - 1] = 0.0;
    }
    if p.i > 0 {
        mask[p.i - 1] = p.a as f32;
    }
    if p.j < cfg.spec.l() {
        mask[p.j - 1] = p.b as f32;
    }
    mask
}

/// Is this probe a no-op on the vanilla network (I = 0 by definition)?
pub fn is_identity_probe(cfg: &ArchConfig, p: &Probe) -> bool {
    if p.j == p.i + 1 {
        return false; // size-one blocks are re-initialized, never no-ops
    }
    probe_mask(cfg, p) == cfg.spec.default_mask()
}

pub struct ImportanceEvaluator<'e> {
    pub engine: &'e Engine,
    pub arch: ArchEntry,
    pub cfg: ArchConfig,
    pub pretrained: ParamSet,
    pub icfg: ImportanceConfig,
}

impl<'e> ImportanceEvaluator<'e> {
    /// Evaluate one probe: short finetune from the pretrained weight
    /// with the probe mask, then val accuracy delta vs `base_acc`.
    pub fn eval_probe(
        &self,
        p: &Probe,
        batcher: &mut Batcher,
        base_acc: f64,
    ) -> Result<f64> {
        if is_identity_probe(&self.cfg, p) {
            return Ok(0.0);
        }
        let mut ts = TrainState::from_checkpoint(&self.arch, &self.pretrained)?;
        if p.j == p.i + 1 {
            // size-one block: re-init the layer (B.3)
            let mut rng = Rng::new(
                self.icfg.seed ^ ((p.i as u64) << 32 | p.j as u64) ^ ((p.a as u64) << 8 | p.b as u64),
            );
            ts.reinit_layer(&self.arch, p.j, &mut rng)?;
        }
        let mask = probe_mask(&self.cfg, p);
        let trainer = Trainer::new(self.engine, &self.arch, mask.clone());
        let tcfg = TrainConfig {
            steps: self.icfg.steps,
            base_lr: self.icfg.lr,
            warmup_steps: 1,
            log_every: usize::MAX,
            final_lr_frac: 0.5,
        };
        let step_def = self.arch.artifact("train_step")?;
        trainer.run(step_def, &mut ts, batcher, &tcfg, None)?;
        let eval_def = self.arch.artifact("eval_step")?;
        let r = eval_masked_subset(
            self.engine,
            eval_def,
            &ts,
            &mask,
            batcher,
            self.arch.eval_batch,
            self.icfg.eval_batches,
        )?;
        Ok(r.acc - base_acc)
    }

    /// Evaluate every probe in the arch config into an ImpTable.
    pub fn eval_all(&self, batcher: &mut Batcher, base_acc: f64) -> Result<ImpTable> {
        let mut table = ImpTable::new(
            base_acc,
            &format!("steps={} lr={}", self.icfg.steps, self.icfg.lr),
        );
        let total = self.cfg.probes.len();
        for (n, p) in self.cfg.probes.clone().iter().enumerate() {
            let v = self.eval_probe(p, batcher, base_acc)?;
            if self.icfg.verbose {
                println!(
                    "  probe {:>3}/{} ({},{},{},{}) I = {v:+.4}",
                    n + 1,
                    total,
                    p.i,
                    p.j,
                    p.a,
                    p.b
                );
            }
            table.insert(p.i, p.j, p.a, p.b, v);
        }
        Ok(table)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::testutil::tiny_config;

    #[test]
    fn probe_mask_deactivates_interior() {
        let cfg = tiny_config();
        let p = Probe { i: 1, j: 4, a: 1, b: 0 };
        let m = probe_mask(&cfg, &p);
        // default [1,1,1,0,1,1]; interior layers 2,3 off; endpoint 1 on,
        // endpoint 4 state 0 (already id)
        assert_eq!(m, vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn probe_mask_can_add_activation() {
        let cfg = tiny_config();
        let p = Probe { i: 1, j: 4, a: 1, b: 1 };
        let m = probe_mask(&cfg, &p);
        assert_eq!(m[3], 1.0); // relu6 ADDED at the linear bottleneck
    }

    #[test]
    fn identity_probe_detected() {
        let cfg = tiny_config();
        // block (4,6] with default endpoint states and... interior layer 5
        // gets deactivated, so NOT identity
        let p = Probe { i: 4, j: 6, a: 1, b: 1 };
        assert!(!is_identity_probe(&cfg, &p));
        // a singleton is never an identity probe (re-init semantics)
        let p1 = Probe { i: 0, j: 1, a: 1, b: 1 };
        assert!(!is_identity_probe(&cfg, &p1));
        // two adjacent layers with both endpoints at original states and
        // no interior: (1,2] has no interior, endpoints relu6 — but it's
        // size 2? No: (1,2] is size one. Use (1,3]: interior = layer 2.
        let p2 = Probe { i: 1, j: 3, a: 1, b: 1 };
        assert!(!is_identity_probe(&cfg, &p2));
    }
}
