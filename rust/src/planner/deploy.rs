//! `DeployPlanner` — the multi-device deployment planner.
//!
//! The paper's deliverable is a latency-budgeted plan *per device*
//! (Tables 3/6/7 span four GPUs and a Xeon); LayerMerge/DepthShrinker
//! frame compression as picking points on an accuracy–latency curve.
//! This module combines both views: one memoized [`Planner`] per
//! latency source (so every per-device budget sweep costs one DP table
//! build), per-device frontiers via `solve_frontier`, and a JOINT
//! importance–latency Pareto set across devices with full provenance
//! (which source, which budget, which plan) per surviving point.
//!
//! # Pareto dominance
//!
//! Point p dominates q iff p is no slower (`est_ms <= q.est_ms`) AND no
//! less important (`importance >= q.importance`), with at least one
//! strict — `pareto_front` keeps exactly the non-dominated points, and
//! the property tests pin that (a) no surviving joint point is
//! dominated and (b) every per-device frontier point is covered by some
//! joint point.  Provenance (source label, budget, plan) rides along so
//! every surviving point can be re-priced on its own device.
//!
//! # Tick-rounding semantics
//!
//! The DP runs in integer ticks (`BlockLatencies::ms_to_ticks`: ms *
//! scale, rounded, clamped to >= 1 tick so no block is ever free);
//! real milliseconds and ticks therefore disagree by up to half a tick
//! per block.  `calibrate` closes that gap: it binary-searches the
//! integer budget T0 against a target merged-network latency in REAL
//! milliseconds, then scans the O(L)-wide rounding window top-down
//! (exact without assuming real-ms monotonicity in T0), at O(L) per
//! probe on the memoized table.

use crate::importance::normalize;
use crate::importance::table::ImpTable;
use crate::latency::table::BlockLatencies;
use crate::model::spec::ArchConfig;
use crate::planner::frontier::{Planner, Space, TableImportance};
use crate::planner::solver::{ImportanceProvider, PlanOutcome};

/// The default budget ladder every serving consumer picks plans from:
/// `(points, lo_frac, hi_frac)` of vanilla latency.  One definition so
/// the CLI, bench_serve, examples, and `Pipeline::serve_plans` cannot
/// drift apart when the ladder is retuned.
pub const SERVE_LADDER: (usize, f64, f64) = (12, 0.45, 0.95);

/// One surviving frontier point, with provenance.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// latency-source label (device provenance)
    pub source: String,
    pub source_idx: usize,
    /// solver-family label (`Space::label`) — which search space won
    /// this point when frontiers mix solver families
    pub solver: &'static str,
    /// the budget that produced the plan
    pub t0_ms: f64,
    /// merged-network latency in real (unrounded) ms under its source
    pub est_ms: f64,
    pub plan: PlanOutcome,
}

impl ParetoPoint {
    pub fn importance(&self) -> f64 {
        self.plan.imp_total
    }

    /// Strict Pareto dominance: no worse on either axis, better on one.
    pub fn dominates(&self, o: &ParetoPoint) -> bool {
        self.est_ms <= o.est_ms
            && self.plan.imp_total >= o.plan.imp_total
            && (self.est_ms < o.est_ms || self.plan.imp_total > o.plan.imp_total)
    }

    /// Weak dominance: at least as good on both axes (equality counts).
    pub fn covers(&self, o: &ParetoPoint) -> bool {
        self.est_ms <= o.est_ms && self.plan.imp_total >= o.plan.imp_total
    }
}

/// A registered latency source: its measured table plus the memoized
/// planner built over it (stage-1/stage-3 products shared by every
/// budget this source is ever asked about).
pub struct DeploySource<P: ImportanceProvider> {
    pub label: String,
    pub lat: BlockLatencies,
    pub planner: Planner<P>,
}

pub struct DeployPlanner<P: ImportanceProvider> {
    l: usize,
    space: Space,
    sources: Vec<DeploySource<P>>,
}

impl<P: ImportanceProvider> DeployPlanner<P> {
    pub fn new(l: usize, space: Space) -> DeployPlanner<P> {
        DeployPlanner { l, space, sources: Vec::new() }
    }

    pub fn l(&self) -> usize {
        self.l
    }

    pub fn space(&self) -> Space {
        self.space
    }

    /// Register a source; builds its memoized planner once.  Returns the
    /// source index used by the query methods.
    pub fn add_source(&mut self, lat: BlockLatencies, imp: P) -> usize {
        let planner = Planner::new(&lat.to_lat_table(self.l), imp);
        self.sources.push(DeploySource { label: lat.source.clone(), lat, planner });
        self.sources.len() - 1
    }

    pub fn sources(&self) -> &[DeploySource<P>] {
        &self.sources
    }

    pub fn is_empty(&self) -> bool {
        self.sources.is_empty()
    }

    /// Uncompressed (all-singleton) network latency under source `idx`.
    pub fn vanilla_ms(&self, idx: usize) -> Option<f64> {
        let singles: Vec<(usize, usize)> = (0..self.l).map(|i| (i, i + 1)).collect();
        self.sources[idx].lat.network_ms(&singles)
    }

    /// Descending budget ladder for source `idx`: `points` budgets from
    /// `hi_frac` down to `lo_frac` of that source's vanilla latency.
    pub fn default_budgets(&self, idx: usize, points: usize, lo_frac: f64, hi_frac: f64) -> Vec<f64> {
        let Some(vanilla) = self.vanilla_ms(idx) else {
            return Vec::new();
        };
        (0..points)
            .map(|n| vanilla * (hi_frac - (hi_frac - lo_frac) * n as f64 / (points - 1).max(1) as f64))
            .collect()
    }

    fn point(&self, idx: usize, space: Space, t0_ms: f64, plan: PlanOutcome) -> ParetoPoint {
        let s = &self.sources[idx];
        // price KEPT segments only: a deleted span is an identity and
        // must not be billed as a merged convolution
        let segs = plan.kept_segments(self.l);
        let est_ms = s.lat.network_ms(&segs).unwrap_or_else(|| s.lat.ticks_to_ms(plan.est_ticks));
        ParetoPoint {
            source: s.label.clone(),
            source_idx: idx,
            solver: space.label(),
            t0_ms,
            est_ms,
            plan,
        }
    }

    /// Per-source frontier: the plan per budget, from ONE DP table pass
    /// on the memoized planner.  Position-aligned with `budgets_ms`
    /// (None where the budget is infeasible) so callers keep the
    /// budget->plan correspondence without re-matching on floats.
    pub fn frontier(&self, idx: usize, budgets_ms: &[f64]) -> Vec<Option<ParetoPoint>> {
        self.frontier_in(idx, self.space, budgets_ms)
    }

    /// Same, in an explicit solution space.  The memoized planner holds
    /// one table per space (stage 1 and stage 3 shared), so mixing
    /// solver families over one source costs one extra table build, not
    /// a re-measure.
    pub fn frontier_in(
        &self,
        idx: usize,
        space: Space,
        budgets_ms: &[f64],
    ) -> Vec<Option<ParetoPoint>> {
        let s = &self.sources[idx];
        let ticks: Vec<u64> = budgets_ms.iter().map(|&ms| s.lat.ms_to_ticks(ms)).collect();
        s.planner
            .solve_frontier(space, &ticks)
            .into_iter()
            .zip(budgets_ms)
            .map(|(sol, &ms)| sol.map(|plan| self.point(idx, space, ms, plan)))
            .collect()
    }

    /// The joint cross-device Pareto set: per-source frontiers merged
    /// and dominance-filtered.  `budgets_ms[k]` is source k's ladder.
    pub fn joint_pareto(&self, budgets_ms: &[Vec<f64>]) -> Vec<ParetoPoint> {
        self.joint_pareto_spaces(&[self.space], budgets_ms)
    }

    /// The joint Pareto set across devices AND solver families: every
    /// (source, space) frontier merged, dominance-filtered, with each
    /// surviving point's `solver` provenance recording which family won
    /// it.  `budgets_ms[k]` is source k's ladder (shared by spaces).
    pub fn joint_pareto_spaces(
        &self,
        spaces: &[Space],
        budgets_ms: &[Vec<f64>],
    ) -> Vec<ParetoPoint> {
        assert_eq!(budgets_ms.len(), self.sources.len(), "one budget ladder per source");
        assert!(!spaces.is_empty(), "at least one solver family");
        let mut all = Vec::new();
        for &space in spaces {
            for (idx, budgets) in budgets_ms.iter().enumerate() {
                all.extend(self.frontier_in(idx, space, budgets).into_iter().flatten());
            }
        }
        pareto_front(all)
    }

    /// Same, on every source's default ladder.
    pub fn joint_pareto_default(&self, points: usize, lo_frac: f64, hi_frac: f64) -> Vec<ParetoPoint> {
        let ladders: Vec<Vec<f64>> = (0..self.sources.len())
            .map(|idx| self.default_budgets(idx, points, lo_frac, hi_frac))
            .collect();
        self.joint_pareto(&ladders)
    }

    /// The canonical serving work list: [`DeployPlanner::frontier_plans`]
    /// on the one ladder every serving consumer shares
    /// ([`SERVE_LADDER`]) — CLI, bench, example, and
    /// `Pipeline::serve_plans` all pick from the same frontier.
    pub fn serve_plans(&self, idx: usize, n: usize) -> Vec<ParetoPoint> {
        let (points, lo, hi) = SERVE_LADDER;
        self.frontier_plans(idx, n, points, lo, hi)
    }

    /// The serving work list: up to `n` DISTINCT plans spread across
    /// source `idx`'s frontier, ordered most-important (slowest) first
    /// — what the multi-plan serving engine keeps resident
    /// (`serve::multi_plan`).  Built from the source's default budget
    /// ladder (`points` budgets from `lo_frac` to `hi_frac` of
    /// vanilla), dominance-filtered, deduplicated by (S, A), with the
    /// two extremes always included and interior picks spread evenly by
    /// latency.
    pub fn frontier_plans(
        &self,
        idx: usize,
        n: usize,
        points: usize,
        lo_frac: f64,
        hi_frac: f64,
    ) -> Vec<ParetoPoint> {
        if n == 0 {
            return Vec::new();
        }
        // ladder needs at least n rungs to have a chance of n distinct
        // plans (capped: a serving engine never wants hundreds resident)
        let budgets = self.default_budgets(idx, points.max(n.min(256)), lo_frac, hi_frac);
        let all: Vec<ParetoPoint> = self.frontier(idx, &budgets).into_iter().flatten().collect();
        // dominance filter + (est, imp)-dedup, then drop plan-identical
        // points (different budgets often yield the same (S, A))
        let mut front = pareto_front(all);
        let mut distinct: Vec<ParetoPoint> = Vec::new();
        for p in front.drain(..) {
            if !distinct.iter().any(|q| q.plan.s == p.plan.s && q.plan.a == p.plan.a) {
                distinct.push(p);
            }
        }
        // pareto_front sorts latency ascending; flip to most-accurate
        // (slowest) first — plan 0 is the server's preferred plan
        distinct.reverse();
        if distinct.len() <= n {
            return distinct;
        }
        if n == 1 {
            // single-plan engine: the most accurate feasible plan
            return vec![distinct[0].clone()];
        }
        // even spread by rank, endpoints pinned
        let last = distinct.len() - 1;
        let mut picked: Vec<usize> = (0..n)
            .map(|k| (k as f64 * last as f64 / (n - 1) as f64).round() as usize)
            .collect();
        picked.dedup();
        picked.into_iter().map(|i| distinct[i].clone()).collect()
    }

    /// Auto-calibrate the integer budget against `target_ms`: the plan
    /// of the LARGEST budget whose DP optimum's merged-network latency
    /// in REAL ms stays <= target.  The objective is weakly monotone in
    /// T0, so that plan is importance-optimal among every budget's
    /// optimum that meets the target.  Returns None when no feasible
    /// budget does.
    ///
    /// Exact without assuming real-ms monotonicity: each block's ticks
    /// differ from ms*scale by at most half a tick (plus the >=1
    /// clamp), so every feasible budget at or below
    /// `ms_to_ticks(target) - L` provably meets the target, and the
    /// question is only decided inside the O(L)-wide tick window up to
    /// the ceiling — scanned top-down at O(L) per probe on the ONE
    /// memoized table (built once at the ceiling; a feasibility binary
    /// search bounds the window from below).
    pub fn calibrate(&self, idx: usize, target_ms: f64) -> Option<ParetoPoint> {
        if target_ms <= 0.0 {
            return None;
        }
        let s = &self.sources[idx];
        let l = self.l as u64;
        // ceiling: the target in ticks plus the worst-case rounding
        // slack (half a tick per block over <= L blocks) — but never
        // beyond the table-derived maximum (no plan can cost more than
        // every block summed, so larger budgets cannot change the
        // optimum); this bounds the DP table by MEASURED data instead
        // of the user-supplied target, which would otherwise let an
        // absurd --target-ms allocate an O(L * target * scale) table
        let cap = s
            .lat
            .entries
            .iter()
            .map(|&(_, _, ms)| (ms * s.lat.scale).round().max(1.0) as u64)
            .sum::<u64>()
            .saturating_add(2);
        let hi = s.lat.ms_to_ticks(target_ms).saturating_add(l + 2).min(cap);
        // one table build at the ceiling; every probe below extracts
        s.planner.solve(self.space, hi)?;
        let probe = |t0: u64| -> Option<(f64, PlanOutcome)> {
            let plan = s.planner.solve(self.space, t0)?;
            let segs = plan.kept_segments(self.l);
            let ms = s.lat.network_ms(&segs)?;
            Some((ms, plan))
        };
        // if the ceiling's optimum already meets the target it is THE
        // answer — no smaller budget can beat its importance
        if let Some((ms, plan)) = probe(hi) {
            if ms <= target_ms {
                return Some(self.point(idx, self.space, s.lat.ticks_to_ms(hi), plan));
            }
        }
        // smallest feasible budget (feasibility IS monotone in T0)
        let (mut a, mut b) = (1u64, hi);
        while a < b {
            let m = a + (b - a) / 2;
            if s.planner.solve(self.space, m).is_some() {
                b = m;
            } else {
                a = m + 1;
            }
        }
        let t_min = a;
        // any feasible budget at or below `floor` meets the target by
        // the rounding-slack bound, so scanning (max(floor, t_min)..=hi]
        // top-down finds the largest qualifying budget exactly
        let floor = s.lat.ms_to_ticks(target_ms).saturating_sub(l);
        for t0 in (floor.max(t_min).max(1)..=hi).rev() {
            if let Some((ms, plan)) = probe(t0) {
                if ms <= target_ms {
                    // t0_ms records the PRODUCING budget (round-trips
                    // through ms_to_ticks), not the requested target
                    return Some(self.point(idx, self.space, s.lat.ticks_to_ms(t0), plan));
                }
            }
        }
        None
    }
}

/// Build a deployment planner over pre-measured tables with ONE shared
/// importance view (importance is a property of the network, not the
/// hardware; B.3-normalized once when `alpha != 0`).  The single
/// registration path behind `Pipeline::plan_deploy` (disk-cached
/// tables) and the artifact-free CLI sweep (directly measured tables).
/// A deletion view (`del`, normalized under the same alpha) arms the
/// layer-merge space; without one `Space::LayerMerge` degenerates to
/// `Space::Extended`.
pub fn deploy_from_tables(
    cfg: &ArchConfig,
    lats: Vec<BlockLatencies>,
    imp: &ImpTable,
    del: Option<&ImpTable>,
    alpha: f64,
    space: Space,
) -> DeployPlanner<TableImportance> {
    let mut imp = imp.clone();
    if alpha != 0.0 {
        normalize::normalize(&mut imp, alpha);
    }
    let del = del.map(|d| {
        let mut d = d.clone();
        if alpha != 0.0 {
            normalize::normalize(&mut d, alpha);
        }
        d
    });
    let mut dp = DeployPlanner::new(cfg.spec.l(), space);
    for lat in lats {
        let ti = match &del {
            Some(d) => TableImportance::with_deletion(cfg, imp.clone(), d.clone()),
            None => TableImportance::new(cfg, imp.clone()),
        };
        dp.add_source(lat, ti);
    }
    dp
}

/// Dominance filter: the non-dominated subset, sorted by latency
/// ascending (importance then strictly ascends).  Duplicate
/// (latency, importance) pairs keep their first representative.
pub fn pareto_front(mut points: Vec<ParetoPoint>) -> Vec<ParetoPoint> {
    // total_cmp: a NaN estimate (e.g. the uncompressed-fallback point)
    // must not panic the dominance filter — it orders last and loses
    points.sort_by(|a, b| {
        a.est_ms.total_cmp(&b.est_ms).then(b.plan.imp_total.total_cmp(&a.plan.imp_total))
    });
    let mut out: Vec<ParetoPoint> = Vec::new();
    let mut best_imp = f64::NEG_INFINITY;
    for p in points {
        // sorted by (est asc, imp desc): p survives iff it strictly
        // beats every earlier point's importance
        if p.plan.imp_total > best_imp {
            best_imp = p.plan.imp_total;
            out.push(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::proxy_importance;
    use crate::dp::stage1::{LatTable, INF};
    use crate::latency::source::Analytical;
    use crate::latency::{devices, gpu_model::ExecMode};
    use crate::model::spec::testutil::tiny_config;
    use crate::planner::frontier::TableImportance;
    use crate::planner::testkit::RandInstance;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// BlockLatencies view of a random instance's tick table (1 tick =
    /// 1 ms, so to_lat_table reproduces it exactly).
    fn lat_of(t: &LatTable, label: &str) -> BlockLatencies {
        let mut entries = Vec::new();
        for i in 0..t.l {
            for j in i + 1..=t.l {
                if t.get(i, j) < INF {
                    entries.push((i, j, t.get(i, j) as f64));
                }
            }
        }
        BlockLatencies::new(label.into(), 1, 1.0, entries)
    }

    fn rand_deploy_in(
        rng: &mut Rng,
        l: usize,
        n_sources: usize,
        space: Space,
    ) -> DeployPlanner<RandInstance> {
        let mut dp = DeployPlanner::new(l, space);
        for k in 0..n_sources {
            let inst = RandInstance::gen(rng, l);
            let lat = lat_of(&inst.t, &format!("rand/{k}"));
            dp.add_source(lat, inst);
        }
        dp
    }

    fn rand_deploy(rng: &mut Rng, l: usize, n_sources: usize) -> DeployPlanner<RandInstance> {
        rand_deploy_in(rng, l, n_sources, Space::Extended)
    }

    fn ladders(dp: &DeployPlanner<RandInstance>, rng: &mut Rng) -> Vec<Vec<f64>> {
        (0..dp.sources().len())
            .map(|_| (0..4).map(|_| 5.0 + rng.below(140) as f64).collect())
            .collect()
    }

    #[test]
    fn joint_set_has_no_dominated_point() {
        forall(20, 71, |rng| {
            let l = 2 + rng.below(5);
            let dp = rand_deploy(rng, l, 1 + rng.below(3));
            let budgets = ladders(&dp, rng);
            let joint = dp.joint_pareto(&budgets);
            for (n, p) in joint.iter().enumerate() {
                for (m, q) in joint.iter().enumerate() {
                    if n != m {
                        crate::prop_assert!(
                            !q.dominates(p),
                            "joint point {n} ({}, {}) dominated by {m} ({}, {})",
                            p.est_ms,
                            p.plan.imp_total,
                            q.est_ms,
                            q.plan.imp_total
                        );
                    }
                }
            }
            // and it is sorted: latency ascending, importance ascending
            for w in joint.windows(2) {
                crate::prop_assert!(w[0].est_ms <= w[1].est_ms, "joint set unsorted");
                crate::prop_assert!(
                    w[0].plan.imp_total < w[1].plan.imp_total,
                    "importance not strictly ascending along the front"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn every_per_device_point_is_in_joint_or_covered() {
        forall(20, 72, |rng| {
            let l = 2 + rng.below(5);
            let dp = rand_deploy(rng, l, 1 + rng.below(3));
            let budgets = ladders(&dp, rng);
            let joint = dp.joint_pareto(&budgets);
            for (idx, ladder) in budgets.iter().enumerate() {
                let front = dp.frontier(idx, ladder);
                crate::prop_assert!(
                    front.len() == ladder.len(),
                    "frontier not position-aligned with its budget ladder"
                );
                for p in front.into_iter().flatten() {
                    crate::prop_assert!(
                        joint.iter().any(|q| q.covers(&p)),
                        "frontier point ({}, {}) of source {idx} neither in the joint \
                         set nor dominated",
                        p.est_ms,
                        p.plan.imp_total
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn joint_provenance_points_back_to_real_frontier_points() {
        forall(10, 73, |rng| {
            let l = 3 + rng.below(4);
            let dp = rand_deploy(rng, l, 2);
            let budgets = ladders(&dp, rng);
            for p in dp.joint_pareto(&budgets) {
                crate::prop_assert!(p.source_idx < dp.sources().len(), "bad source index");
                crate::prop_assert!(
                    p.source == dp.sources()[p.source_idx].label,
                    "label/index provenance mismatch"
                );
                // the plan re-prices to the recorded latency under ITS
                // OWN source table (kept segments only — deleted spans
                // are identities and must not be billed)
                let segs = p.plan.kept_segments(l);
                let ms = dp.sources()[p.source_idx].lat.network_ms(&segs);
                crate::prop_assert!(
                    ms == Some(p.est_ms),
                    "est_ms {} does not re-price ({:?})",
                    p.est_ms,
                    ms
                );
            }
            Ok(())
        });
    }

    #[test]
    fn mixed_family_joint_pareto_has_solver_provenance() {
        // frontiers from every solver family merged into one joint set:
        // still dominance-free, every point labelled with the family
        // that produced it, and the layer-merge family never absent for
        // a reason other than losing on merit (its optimum dominates
        // the extended optimum at equal budget by construction)
        forall(15, 76, |rng| {
            let l = 3 + rng.below(4);
            let dp = rand_deploy(rng, l, 1 + rng.below(2));
            let budgets = ladders(&dp, rng);
            let spaces = [Space::Base, Space::Extended, Space::LayerMerge];
            let joint = dp.joint_pareto_spaces(&spaces, &budgets);
            let labels: Vec<&'static str> = spaces.iter().map(|s| s.label()).collect();
            for p in &joint {
                crate::prop_assert!(
                    labels.contains(&p.solver),
                    "unknown solver label {}",
                    p.solver
                );
                crate::prop_assert!(
                    p.solver == "layermerge" || p.plan.deleted.is_empty(),
                    "non-layer-merge point carries deletions"
                );
            }
            for (n, p) in joint.iter().enumerate() {
                for (m, q) in joint.iter().enumerate() {
                    if n != m {
                        crate::prop_assert!(!q.dominates(p), "dominated point in mixed joint set");
                    }
                }
            }
            // the mixed set weakly covers the single-family set: adding
            // families can only improve the front
            for p in dp.joint_pareto(&budgets) {
                crate::prop_assert!(
                    joint.iter().any(|q| q.covers(&p)),
                    "mixed-family front fails to cover a single-family point"
                );
            }
            Ok(())
        });
    }

    /// The acceptance pin: calibrating to an ACHIEVABLE target lands
    /// within one tick of it, on every paper device.
    #[test]
    fn calibration_lands_within_one_tick_of_achievable_targets() {
        let cfg = tiny_config();
        let l = cfg.spec.l();
        let scale = 1.0e5; // fine ticks so rounding cannot mask a miss
        let mut dp = DeployPlanner::new(l, Space::Extended);
        for dev in devices::ALL {
            let mut src = Analytical { dev, mode: ExecMode::Fused };
            let lat = BlockLatencies::measure(&cfg, &mut src, 64, scale).unwrap();
            dp.add_source(lat, TableImportance::new(&cfg, proxy_importance(&cfg)));
        }
        for idx in 0..dp.sources().len() {
            let budgets = dp.default_budgets(idx, 6, 0.5, 0.95);
            let front: Vec<ParetoPoint> =
                dp.frontier(idx, &budgets).into_iter().flatten().collect();
            assert!(!front.is_empty(), "no feasible budgets on {}", dp.sources()[idx].label);
            let tick_ms = 1.0 / scale;
            for target in front.iter().map(|p| p.est_ms) {
                let got = dp.calibrate(idx, target).unwrap_or_else(|| {
                    panic!("calibration missed achievable target {target} on source {idx}")
                });
                assert!(
                    got.est_ms <= target + 1e-12,
                    "calibrated plan overshoots: {} > {target}",
                    got.est_ms
                );
                assert!(
                    target - got.est_ms <= tick_ms + 1e-12,
                    "calibrated plan {} more than one tick below target {target} \
                     on {}",
                    got.est_ms,
                    dp.sources()[idx].label
                );
                // and it is importance-optimal among frontier plans
                // that also meet the target
                for p in front.iter().filter(|p| p.est_ms <= target) {
                    assert!(
                        got.plan.imp_total >= p.plan.imp_total - 1e-9,
                        "frontier point ({}, {}) beats calibrated ({}, {})",
                        p.est_ms,
                        p.plan.imp_total,
                        got.est_ms,
                        got.plan.imp_total
                    );
                }
            }
        }
    }

    #[test]
    fn frontier_plans_are_distinct_spread_and_ordered() {
        forall(20, 75, |rng| {
            let l = 3 + rng.below(4);
            let dp = rand_deploy(rng, l, 1);
            let n = 1 + rng.below(4);
            let plans = dp.frontier_plans(0, n, 12, 0.4, 0.95);
            crate::prop_assert!(plans.len() <= n, "{} plans for n={n}", plans.len());
            // most-accurate first: est_ms and importance both descend
            for w in plans.windows(2) {
                crate::prop_assert!(
                    w[0].est_ms >= w[1].est_ms && w[0].plan.imp_total >= w[1].plan.imp_total,
                    "work list not ordered most-accurate (slowest) first"
                );
            }
            // distinct (S, A) per entry, and every entry on the frontier
            // (no entry dominated by another)
            for (i, p) in plans.iter().enumerate() {
                for (j, q) in plans.iter().enumerate() {
                    if i != j {
                        crate::prop_assert!(
                            p.plan.s != q.plan.s || p.plan.a != q.plan.a,
                            "duplicate plan in the work list"
                        );
                        crate::prop_assert!(!q.dominates(p), "dominated plan in the work list");
                    }
                }
            }
            // with capacity for more than one plan, the extremes of the
            // distinct frontier must both be present (n=12 keeps the
            // budget ladder identical to the picks above, so `full` IS
            // the distinct set the picker sampled from)
            let full = dp.frontier_plans(0, 12, 12, 0.4, 0.95);
            if !full.is_empty() && n >= 2 && plans.len() >= 2 {
                crate::prop_assert!(
                    plans[0].plan.s == full[0].plan.s
                        && plans[plans.len() - 1].plan.s == full[full.len() - 1].plan.s,
                    "endpoints of the frontier must be pinned"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn calibration_refuses_unreachable_targets() {
        let cfg = tiny_config();
        let l = cfg.spec.l();
        let mut dp = DeployPlanner::new(l, Space::Extended);
        let mut src = Analytical { dev: &devices::RTX_2080_TI, mode: ExecMode::Fused };
        let lat = BlockLatencies::measure(&cfg, &mut src, 64, 1.0e5).unwrap();
        let idx = dp.add_source(lat, TableImportance::new(&cfg, proxy_importance(&cfg)));
        // fastest possible network: below the cheapest single block
        let floor = dp.sources()[idx]
            .lat
            .entries
            .iter()
            .map(|e| e.2)
            .fold(f64::INFINITY, f64::min);
        assert!(dp.calibrate(idx, floor * 0.5).is_none());
        assert!(dp.calibrate(idx, 0.0).is_none());
        assert!(dp.calibrate(idx, -1.0).is_none());
    }

    #[test]
    fn layer_merge_points_price_kept_segments_only() {
        // a deployment planner in the layer-merge space: every frontier
        // and calibration point must re-price from kept segments (a
        // deleted span billed as a conv would overstate est_ms)
        forall(15, 77, |rng| {
            let l = 3 + rng.below(4);
            let dp = rand_deploy_in(rng, l, 1, Space::LayerMerge);
            let budgets: Vec<f64> = (0..5).map(|_| 2.0 + rng.below(120) as f64).collect();
            for p in dp.frontier(0, &budgets).into_iter().flatten() {
                assert_eq!(p.solver, "layermerge");
                let ms = dp.sources()[0].lat.network_ms(&p.plan.kept_segments(l));
                crate::prop_assert!(ms == Some(p.est_ms), "est_ms does not re-price");
                // ticks agree with the ms pricing at scale 1.0 (1 tick
                // = 1 ms in lat_of): deleted spans cost nothing
                crate::prop_assert!(
                    (p.est_ms - p.plan.est_ticks as f64).abs() < 1e-9,
                    "tick/ms pricing diverges on a layer-merge plan"
                );
            }
            if let Some(got) = dp.calibrate(0, 3.0 + rng.below(120) as f64) {
                let ms = dp.sources()[0].lat.network_ms(&got.plan.kept_segments(l));
                crate::prop_assert!(ms == Some(got.est_ms), "calibrated est_ms does not re-price");
            }
            Ok(())
        });
    }

    #[test]
    fn calibration_never_overshoots_on_random_instances() {
        forall(20, 74, |rng| {
            let l = 3 + rng.below(4);
            let dp = rand_deploy(rng, l, 1);
            for _ in 0..4 {
                let target = 3.0 + rng.below(160) as f64;
                if let Some(got) = dp.calibrate(0, target) {
                    crate::prop_assert!(
                        got.est_ms <= target + 1e-12,
                        "calibrated plan {} overshoots target {target}",
                        got.est_ms
                    );
                    // the result re-prices under the source table
                    let segs = got.plan.kept_segments(l);
                    let ms = dp.sources()[0].lat.network_ms(&segs);
                    crate::prop_assert!(ms == Some(got.est_ms), "est_ms does not re-price");
                }
            }
            Ok(())
        });
    }
}
