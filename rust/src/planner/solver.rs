//! The uniform solver surface over the paper's three solution methods.
//!
//! Every solver consumes the same inputs — an integer `LatTable` plus
//! an `ImportanceProvider` — and produces the same `PlanOutcome`, so
//! the exact-but-exponential oracle, the base two-stage DP (Algorithms
//! 1+2) and the extended-space DP (Algorithms 3+4) are interchangeable
//! and cross-validatable:
//!
//!   BruteSolver     — enumerates the space directly (tests only)
//!   TwoStageSolver  — base space, Propositions 4.1/4.2 exact
//!   ExtendedSolver  — (boundary, activation-state) space, Appendix B.1
//!
//! `solve_frontier` exploits that one stage-2/stage-4 DP table built at
//! the LARGEST budget already encodes the optimum for every smaller
//! budget (columns are budget-local), so a K-point budget sweep costs
//! one table build + K reconstructions instead of K full solves.  For
//! stateful reuse across calls (the coordinator path) see
//! [`super::frontier::Planner`].

use crate::dp::brute;
use crate::dp::extended;
use crate::dp::stage1::{self, LatTable};
use crate::dp::stage2::{self, NEG_INF};

/// Both importance views a solver may need.  `base` is the base-space
/// I[i, j] with the endpoint activations at their ORIGINAL states;
/// `ext` is the extended-space I[i, j, d_i, d_j].  NEG_INF marks
/// invalid blocks in both views.
pub trait ImportanceProvider {
    fn base(&self, i: usize, j: usize) -> f64;
    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64;
}

impl<T: ImportanceProvider + ?Sized> ImportanceProvider for &T {
    fn base(&self, i: usize, j: usize) -> f64 {
        (**self).base(i, j)
    }

    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        (**self).ext(i, j, a, b)
    }
}

/// The uniform solver output: kept activations A, added-activation
/// boundaries B (== A in the base space), merge boundaries S, surrogate
/// objective, and the integer-tick latency of the merged network.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOutcome {
    /// activation layers kept (ascending, subset of S)
    pub a: Vec<usize>,
    /// block boundaries incl. id joints (ascending, superset of A)
    pub b: Vec<usize>,
    /// merge boundaries (ascending)
    pub s: Vec<usize>,
    /// surrogate objective sum I
    pub imp_total: f64,
    /// latency of the merged network in integer ticks (< the budget)
    pub est_ticks: u64,
}

/// One solution method; `solve` honours the strict budget
/// `est_ticks < t0`.
pub trait Solver {
    fn name(&self) -> &'static str;

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome>;

    /// Plans for every budget point (same order as `budgets`).  The
    /// default re-solves per budget; DP solvers override it with the
    /// one-pass table sweep.  Either way the result is identical to
    /// calling `solve` per budget — property-tested below.
    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        budgets.iter().map(|&t0| self.solve(t, imp, t0)).collect()
    }
}

/// Exact enumeration of the solution space (paper Eq. 6 / Eq. 16).
/// Exponential — cross-validation on small L only.
pub struct BruteSolver {
    /// enumerate the extended (A ⊆ B) space instead of the base space
    pub extended: bool,
}

impl Solver for BruteSolver {
    fn name(&self) -> &'static str {
        if self.extended {
            "brute(extended)"
        } else {
            "brute(base)"
        }
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let l = t.l;
        assert!(l <= 16, "BruteSolver is exponential; cross-validation only (L = {l})");
        if self.extended {
            let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
            brute::solve_extended(l, t, &f, t0).map(|sol| PlanOutcome {
                a: sol.a,
                b: sol.b,
                s: sol.s,
                imp_total: sol.objective,
                est_ticks: sol.latency,
            })
        } else {
            let mut m = vec![vec![NEG_INF; l + 1]; l + 1];
            for (i, row) in m.iter_mut().enumerate() {
                for (j, v) in row.iter_mut().enumerate().take(l + 1).skip(i + 1) {
                    *v = imp.base(i, j);
                }
            }
            brute::solve_base(l, t, &m, t0).map(|sol| PlanOutcome {
                b: sol.a.clone(),
                a: sol.a,
                s: sol.s,
                imp_total: sol.objective,
                est_ticks: sol.latency,
            })
        }
    }
}

/// Algorithms 1+2 over the base space (B = A).
pub struct TwoStageSolver;

impl Solver for TwoStageSolver {
    fn name(&self) -> &'static str {
        "two-stage"
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize| imp.base(i, j);
        stage2::solve(t.l, &s1, &f, t0).map(from_base)
    }

    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize| imp.base(i, j);
        let table = stage2::build(t.l, &s1, &f, t0_max);
        budgets.iter().map(|&t0| table.extract(&s1, t0).map(from_base)).collect()
    }
}

/// Algorithms 3+4 over the extended (boundary, activation-state) space.
pub struct ExtendedSolver;

impl Solver for ExtendedSolver {
    fn name(&self) -> &'static str {
        "extended"
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        extended::solve(t.l, &s1, &f, t0).map(from_ext)
    }

    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        let s3 = extended::solve_stage3(t.l, &f);
        let table = extended::build(t.l, &s1, &s3, t0_max);
        budgets.iter().map(|&t0| table.extract(&s1, &s3, t0).map(from_ext)).collect()
    }
}

fn from_base(sol: stage2::Solution) -> PlanOutcome {
    PlanOutcome {
        b: sol.a.clone(),
        a: sol.a,
        s: sol.s,
        imp_total: sol.objective,
        est_ticks: sol.latency,
    }
}

fn from_ext(sol: extended::ExtSolution) -> PlanOutcome {
    PlanOutcome { a: sol.a, b: sol.b, s: sol.s, imp_total: sol.objective, est_ticks: sol.latency }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Rng;

    /// Random dense importance over random merge-legal segments, with
    /// probe-rule-shaped validity (mirrors specs.enumerate_probes):
    /// interior boundaries whose original activation is relu6 cannot be
    /// probed with that endpoint off, virtual endpoints are always on.
    pub struct RandInstance {
        pub l: usize,
        pub t: LatTable,
        ext: Vec<f64>,
        orig_on: Vec<bool>,
    }

    impl RandInstance {
        pub fn gen(rng: &mut Rng, l: usize) -> RandInstance {
            let mut t = LatTable::new(l);
            let mut ext = vec![NEG_INF; (l + 1) * (l + 1) * 4];
            let mut orig_on = vec![true; l + 1];
            for x in 1..l {
                orig_on[x] = rng.uniform() < 0.5;
            }
            for i in 0..l {
                for j in i + 1..=l {
                    let mergeable = j == i + 1 || rng.uniform() < 0.6;
                    if !mergeable {
                        continue;
                    }
                    t.set(i, j, 1 + rng.below(30) as u64);
                    for a in 0..2u8 {
                        for b in 0..2u8 {
                            if i == 0 && a == 0 {
                                continue;
                            }
                            if j == l && b == 0 {
                                continue;
                            }
                            if i > 0 && orig_on[i] && a == 0 {
                                continue;
                            }
                            if j < l && orig_on[j] && b == 0 {
                                continue;
                            }
                            let v = -(rng.uniform() as f64) * (j - i) as f64
                                + 0.1 * (a as f64 + b as f64);
                            ext[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize] = v;
                        }
                    }
                }
            }
            RandInstance { l, t, ext, orig_on }
        }
    }

    impl ImportanceProvider for RandInstance {
        fn base(&self, i: usize, j: usize) -> f64 {
            self.ext(i, j, self.orig_on[i] as u8, self.orig_on[j] as u8)
        }

        fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
            self.ext[((i * (self.l + 1) + j) * 2 + a as usize) * 2 + b as usize]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::RandInstance;
    use super::*;
    use crate::util::prop::forall;

    fn same(a: &Option<PlanOutcome>, b: &Option<PlanOutcome>) -> Result<(), String> {
        match (a, b) {
            (None, None) => Ok(()),
            (Some(x), Some(y)) => {
                // plans must agree exactly (identical tables + tie-breaks);
                // objectives compare with a float tolerance
                if x.a == y.a
                    && x.b == y.b
                    && x.s == y.s
                    && x.est_ticks == y.est_ticks
                    && (x.imp_total - y.imp_total).abs() < 1e-9
                {
                    Ok(())
                } else {
                    Err(format!("plans differ: {x:?} vs {y:?}"))
                }
            }
            _ => Err(format!("feasibility differs: {a:?} vs {b:?}")),
        }
    }

    /// Objectives must match the oracle; the argmax plan may differ on
    /// exact ties, so compare value + feasibility + budget adherence.
    fn same_value(
        got: &Option<PlanOutcome>,
        oracle: &Option<PlanOutcome>,
        t0: u64,
    ) -> Result<(), String> {
        match (got, oracle) {
            (None, None) => Ok(()),
            (Some(g), Some(w)) => {
                if (g.imp_total - w.imp_total).abs() >= 1e-9 {
                    return Err(format!(
                        "objective {} != oracle {} (A={:?} vs {:?}, t0={t0})",
                        g.imp_total, w.imp_total, g.a, w.a
                    ));
                }
                if g.est_ticks >= t0 {
                    return Err(format!("latency {} violates budget {t0}", g.est_ticks));
                }
                Ok(())
            }
            _ => Err(format!(
                "feasibility differs from oracle: {:?} vs {:?} (t0={t0})",
                got.as_ref().map(|x| x.imp_total),
                oracle.as_ref().map(|x| x.imp_total)
            )),
        }
    }

    #[test]
    fn two_stage_matches_brute_oracle() {
        forall(40, 51, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let t0 = 5 + rng.below(120) as u64;
            let got = TwoStageSolver.solve(&inst.t, &inst, t0);
            let want = BruteSolver { extended: false }.solve(&inst.t, &inst, t0);
            same_value(&got, &want, t0)
        });
    }

    #[test]
    fn extended_matches_brute_oracle() {
        forall(30, 52, |rng| {
            let l = 2 + rng.below(5);
            let inst = RandInstance::gen(rng, l);
            let t0 = 5 + rng.below(100) as u64;
            let got = ExtendedSolver.solve(&inst.t, &inst, t0);
            let want = BruteSolver { extended: true }.solve(&inst.t, &inst, t0);
            same_value(&got, &want, t0)
        });
    }

    #[test]
    fn extended_space_dominates_base_space() {
        // the extended space strictly contains the base space, so its
        // optimum can only be better or equal
        forall(30, 53, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let t0 = 10 + rng.below(100) as u64;
            if let (Some(base), Some(ext)) = (
                TwoStageSolver.solve(&inst.t, &inst, t0),
                ExtendedSolver.solve(&inst.t, &inst, t0),
            ) {
                crate::prop_assert!(
                    ext.imp_total >= base.imp_total - 1e-9,
                    "extended {} < base {} at t0={t0}",
                    ext.imp_total,
                    base.imp_total
                );
            }
            Ok(())
        });
    }

    #[test]
    fn frontier_identical_to_per_budget_solves() {
        // the ISSUE acceptance bar: solve_frontier must return plans
        // BYTE-IDENTICAL to independent per-budget solves, for both DP
        // solvers, on arbitrary (unsorted, duplicated) budget lists
        forall(25, 54, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let mut budgets: Vec<u64> =
                (0..(2 + rng.below(6))).map(|_| 5 + rng.below(140) as u64).collect();
            budgets.push(budgets[0]); // duplicate on purpose
            for solver in [&TwoStageSolver as &dyn Solver, &ExtendedSolver as &dyn Solver] {
                let swept = solver.solve_frontier(&inst.t, &inst, &budgets);
                crate::prop_assert!(
                    swept.len() == budgets.len(),
                    "{}: frontier arity {} != {}",
                    solver.name(),
                    swept.len(),
                    budgets.len()
                );
                for (n, &t0) in budgets.iter().enumerate() {
                    let fresh = solver.solve(&inst.t, &inst, t0);
                    if let Err(e) = same(&swept[n], &fresh) {
                        return Err(format!("{} at t0={t0}: {e}", solver.name()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_frontier_is_empty() {
        let mut rng = crate::util::rng::Rng::new(7);
        let inst = RandInstance::gen(&mut rng, 4);
        assert!(TwoStageSolver.solve_frontier(&inst.t, &inst, &[]).is_empty());
        assert!(ExtendedSolver.solve_frontier(&inst.t, &inst, &[]).is_empty());
    }

    #[test]
    fn outcome_invariants() {
        forall(20, 55, |rng| {
            let l = 3 + rng.below(5);
            let inst = RandInstance::gen(rng, l);
            let t0 = 20 + rng.below(120) as u64;
            for solver in [&TwoStageSolver as &dyn Solver, &ExtendedSolver as &dyn Solver] {
                if let Some(out) = solver.solve(&inst.t, &inst, t0) {
                    for x in &out.a {
                        crate::prop_assert!(
                            out.b.contains(x),
                            "{}: A ⊄ B",
                            solver.name()
                        );
                        crate::prop_assert!(
                            out.s.contains(x),
                            "{}: A ⊄ S",
                            solver.name()
                        );
                    }
                    crate::prop_assert!(
                        out.est_ticks < t0,
                        "{}: budget violated",
                        solver.name()
                    );
                }
            }
            Ok(())
        });
    }
}
