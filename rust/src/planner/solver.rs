//! The uniform solver surface over the planner's solution methods.
//!
//! Every solver consumes the same inputs — an integer `LatTable` plus
//! an `ImportanceProvider` — and produces the same `PlanOutcome`, so
//! the exact-but-exponential oracle, the base two-stage DP (Algorithms
//! 1+2), the extended-space DP (Algorithms 3+4), and the layer-merge
//! DP (the LayerMerge follow-up's joint delete × linearize space) are
//! interchangeable and cross-validatable:
//!
//!   BruteSolver      — enumerates its space directly (tests only)
//!   TwoStageSolver   — base space, Propositions 4.1/4.2 exact
//!   ExtendedSolver   — (boundary, activation-state) space, App. B.1
//!   LayerMergeSolver — joint (layer kept/deleted, activation
//!                      kept/linearized) space, dp/layer_merge.rs
//!
//! `solve_frontier` exploits that one DP table built at the LARGEST
//! budget already encodes the optimum for every smaller budget
//! (columns are budget-local), so a K-point budget sweep costs one
//! table build + K reconstructions instead of K full solves.  For
//! stateful reuse across calls (the coordinator path) see
//! [`super::frontier::Planner`].  [`registry`] enumerates the DP
//! solvers with their `Space` labels for differential testing and the
//! CLI `--solver` flag.

use crate::dp::brute;
use crate::dp::extended;
use crate::dp::layer_merge;
use crate::dp::stage1::{self, LatTable};
use crate::dp::stage2::{self, NEG_INF};
use crate::merge::plan::segments_from_s;

use super::frontier::Space;

/// Every importance view a solver may need.  `base` is the base-space
/// I[i, j] with the endpoint activations at their ORIGINAL states;
/// `ext` is the extended-space I[i, j, d_i, d_j]; `del` is the
/// layer-merge deletion view — the importance of REMOVING block
/// (i, j] entirely, NEG_INF where deletion is structurally illegal
/// (the default, so base/extended providers need not implement it).
pub trait ImportanceProvider {
    fn base(&self, i: usize, j: usize) -> f64;
    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64;
    fn del(&self, _i: usize, _j: usize, _a: u8, _b: u8) -> f64 {
        NEG_INF
    }
}

impl<T: ImportanceProvider + ?Sized> ImportanceProvider for &T {
    fn base(&self, i: usize, j: usize) -> f64 {
        (**self).base(i, j)
    }

    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        (**self).ext(i, j, a, b)
    }

    fn del(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        (**self).del(i, j, a, b)
    }
}

/// The uniform solver output: kept activations A, added-activation
/// boundaries B (== A in the base space), merge boundaries S, deleted
/// spans (layer-merge space only; empty otherwise), surrogate
/// objective, and the integer-tick latency of the merged network.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOutcome {
    /// activation layers kept (ascending, subset of S)
    pub a: Vec<usize>,
    /// block boundaries incl. id joints (ascending, superset of A)
    pub b: Vec<usize>,
    /// merge boundaries (ascending)
    pub s: Vec<usize>,
    /// layer spans (i, j] deleted outright (ascending, disjoint; both
    /// endpoints land in S so the span is its own S-segment)
    pub deleted: Vec<(usize, usize)>,
    /// surrogate objective sum I
    pub imp_total: f64,
    /// latency of the merged network in integer ticks (< the budget;
    /// deleted spans contribute zero)
    pub est_ticks: u64,
}

impl PlanOutcome {
    /// The S-segments that remain as real merged convolutions: the full
    /// `segments_from_s` partition of [0, L] minus the deleted spans.
    /// Anything pricing a plan (network_ms, merged execution) must
    /// iterate these, not the raw partition.
    pub fn kept_segments(&self, l: usize) -> Vec<(usize, usize)> {
        segments_from_s(l, &self.s)
            .into_iter()
            .filter(|seg| !self.deleted.contains(seg))
            .collect()
    }
}

/// One solution method; `solve` honours the strict budget
/// `est_ticks < t0`.
pub trait Solver {
    fn name(&self) -> &'static str;

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome>;

    /// Plans for every budget point (same order as `budgets`).  The
    /// default re-solves per budget; DP solvers override it with the
    /// one-pass table sweep.  Either way the result is identical to
    /// calling `solve` per budget — property-tested below.
    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        budgets.iter().map(|&t0| self.solve(t, imp, t0)).collect()
    }
}

/// Exact enumeration of a solution space (paper Eq. 6 / Eq. 16, plus
/// the joint delete × linearize space).  Exponential — cross-validation
/// on small L only.
pub struct BruteSolver {
    /// which space to enumerate
    pub space: Space,
}

impl Solver for BruteSolver {
    fn name(&self) -> &'static str {
        match self.space {
            Space::Base => "brute(base)",
            Space::Extended => "brute(extended)",
            Space::LayerMerge => "brute(layer-merge)",
        }
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let l = t.l;
        assert!(l <= 16, "BruteSolver is exponential; cross-validation only (L = {l})");
        match self.space {
            Space::Base => {
                let mut m = vec![vec![NEG_INF; l + 1]; l + 1];
                for (i, row) in m.iter_mut().enumerate() {
                    for (j, v) in row.iter_mut().enumerate().take(l + 1).skip(i + 1) {
                        *v = imp.base(i, j);
                    }
                }
                brute::solve_base(l, t, &m, t0).map(from_base)
            }
            Space::Extended => {
                let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
                brute::solve_extended(l, t, &f, t0).map(from_ext)
            }
            Space::LayerMerge => {
                let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
                let d = |i: usize, j: usize, a: u8, b: u8| imp.del(i, j, a, b);
                brute::solve_layer_merge(l, t, &f, &d, t0).map(from_lm)
            }
        }
    }
}

/// Algorithms 1+2 over the base space (B = A).
pub struct TwoStageSolver;

impl Solver for TwoStageSolver {
    fn name(&self) -> &'static str {
        "two-stage"
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize| imp.base(i, j);
        stage2::solve(t.l, &s1, &f, t0).map(from_base)
    }

    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize| imp.base(i, j);
        let table = stage2::build(t.l, &s1, &f, t0_max);
        budgets.iter().map(|&t0| table.extract(&s1, t0).map(from_base)).collect()
    }
}

/// Algorithms 3+4 over the extended (boundary, activation-state) space.
pub struct ExtendedSolver;

impl Solver for ExtendedSolver {
    fn name(&self) -> &'static str {
        "extended"
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        extended::solve(t.l, &s1, &f, t0).map(from_ext)
    }

    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        let s3 = extended::solve_stage3(t.l, &f);
        let table = extended::build(t.l, &s1, &s3, t0_max);
        budgets.iter().map(|&t0| table.extract(&s1, &s3, t0).map(from_ext)).collect()
    }
}

/// The LayerMerge follow-up's joint space: every block is kept (merged,
/// priced by stage 1) or deleted (identity, zero ticks, scored by the
/// provider's `del` view), on top of the extended activation states.
/// Strictly contains the extended space (no-delete plans), so its
/// optimum dominates `ExtendedSolver` by construction.
pub struct LayerMergeSolver;

impl Solver for LayerMergeSolver {
    fn name(&self) -> &'static str {
        "layer-merge"
    }

    fn solve(&self, t: &LatTable, imp: &dyn ImportanceProvider, t0: u64) -> Option<PlanOutcome> {
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        let d = |i: usize, j: usize, a: u8, b: u8| imp.del(i, j, a, b);
        layer_merge::solve(t.l, &s1, &f, &d, t0).map(from_lm)
    }

    fn solve_frontier(
        &self,
        t: &LatTable,
        imp: &dyn ImportanceProvider,
        budgets: &[u64],
    ) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        let s1 = stage1::solve(t);
        let f = |i: usize, j: usize, a: u8, b: u8| imp.ext(i, j, a, b);
        let d = |i: usize, j: usize, a: u8, b: u8| imp.del(i, j, a, b);
        let s3 = extended::solve_stage3(t.l, &f);
        let table = layer_merge::build(t.l, &s1, &s3, &d, t0_max);
        budgets.iter().map(|&t0| table.extract(&s1, &s3, t0).map(from_lm)).collect()
    }
}

/// Every registered DP solver paired with its `Space` label — the
/// single source of truth for the CLI `--solver` grammar and the
/// differential test suite (each entry is cross-validated against
/// `BruteSolver { space }` on small instances).
pub fn registry() -> Vec<(Space, Box<dyn Solver>)> {
    vec![
        (Space::Base, Box::new(TwoStageSolver)),
        (Space::Extended, Box::new(ExtendedSolver)),
        (Space::LayerMerge, Box::new(LayerMergeSolver)),
    ]
}

fn from_base(sol: stage2::Solution) -> PlanOutcome {
    PlanOutcome {
        b: sol.a.clone(),
        a: sol.a,
        s: sol.s,
        deleted: Vec::new(),
        imp_total: sol.objective,
        est_ticks: sol.latency,
    }
}

fn from_ext(sol: extended::ExtSolution) -> PlanOutcome {
    PlanOutcome {
        a: sol.a,
        b: sol.b,
        s: sol.s,
        deleted: Vec::new(),
        imp_total: sol.objective,
        est_ticks: sol.latency,
    }
}

fn from_lm(sol: layer_merge::LmSolution) -> PlanOutcome {
    PlanOutcome {
        a: sol.a,
        b: sol.b,
        s: sol.s,
        deleted: sol.deleted,
        imp_total: sol.objective,
        est_ticks: sol.latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::testkit::{recheck_extended_family, RandInstance};
    use crate::util::prop::forall;

    fn same(a: &Option<PlanOutcome>, b: &Option<PlanOutcome>) -> Result<(), String> {
        match (a, b) {
            (None, None) => Ok(()),
            (Some(x), Some(y)) => {
                // plans must agree exactly (identical tables + tie-breaks);
                // objectives compare with a float tolerance
                if x.a == y.a
                    && x.b == y.b
                    && x.s == y.s
                    && x.deleted == y.deleted
                    && x.est_ticks == y.est_ticks
                    && (x.imp_total - y.imp_total).abs() < 1e-9
                {
                    Ok(())
                } else {
                    Err(format!("plans differ: {x:?} vs {y:?}"))
                }
            }
            _ => Err(format!("feasibility differs: {a:?} vs {b:?}")),
        }
    }

    /// Objectives must match the oracle; the argmax plan may differ on
    /// exact ties, so compare value + feasibility + budget adherence.
    fn same_value(
        got: &Option<PlanOutcome>,
        oracle: &Option<PlanOutcome>,
        t0: u64,
    ) -> Result<(), String> {
        match (got, oracle) {
            (None, None) => Ok(()),
            (Some(g), Some(w)) => {
                if (g.imp_total - w.imp_total).abs() >= 1e-9 {
                    return Err(format!(
                        "objective {} != oracle {} (A={:?} vs {:?}, t0={t0})",
                        g.imp_total, w.imp_total, g.a, w.a
                    ));
                }
                if g.est_ticks >= t0 {
                    return Err(format!("latency {} violates budget {t0}", g.est_ticks));
                }
                Ok(())
            }
            _ => Err(format!(
                "feasibility differs from oracle: {:?} vs {:?} (t0={t0})",
                got.as_ref().map(|x| x.imp_total),
                oracle.as_ref().map(|x| x.imp_total)
            )),
        }
    }

    #[test]
    fn two_stage_matches_brute_oracle() {
        forall(40, 51, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let t0 = 5 + rng.below(120) as u64;
            let got = TwoStageSolver.solve(&inst.t, &inst, t0);
            let want = BruteSolver { space: Space::Base }.solve(&inst.t, &inst, t0);
            same_value(&got, &want, t0)
        });
    }

    #[test]
    fn extended_matches_brute_oracle() {
        forall(30, 52, |rng| {
            let l = 2 + rng.below(5);
            let inst = RandInstance::gen(rng, l);
            let t0 = 5 + rng.below(100) as u64;
            let got = ExtendedSolver.solve(&inst.t, &inst, t0);
            let want = BruteSolver { space: Space::Extended }.solve(&inst.t, &inst, t0);
            same_value(&got, &want, t0)
        });
    }

    #[test]
    fn layer_merge_matches_brute_oracle_up_to_l8() {
        // the ISSUE acceptance bar: exact agreement with the exhaustive
        // joint delete x linearize enumeration for every L <= 8
        forall(20, 56, |rng| {
            let l = 2 + rng.below(7); // 2..=8
            let inst = RandInstance::gen(rng, l);
            let t0 = 1 + rng.below(120) as u64;
            let got = LayerMergeSolver.solve(&inst.t, &inst, t0);
            let want = BruteSolver { space: Space::LayerMerge }.solve(&inst.t, &inst, t0);
            same_value(&got, &want, t0)?;
            if let Some(out) = &got {
                recheck_extended_family(&inst.t, &inst, out, t0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn search_space_chain_never_loses() {
        // base ⊂ extended ⊂ layer-merge: at equal budget the optimum is
        // monotone along the chain (a larger space never loses)
        forall(30, 53, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let t0 = 10 + rng.below(100) as u64;
            let solvers = registry();
            let outs: Vec<Option<PlanOutcome>> =
                solvers.iter().map(|(_, s)| s.solve(&inst.t, &inst, t0)).collect();
            for w in outs.windows(2) {
                match (&w[0], &w[1]) {
                    // a larger space can gain feasibility, never lose it
                    (Some(_), None) => {
                        return Err(format!("larger space lost feasibility at t0={t0}"))
                    }
                    (Some(small), Some(big)) => {
                        crate::prop_assert!(
                            big.imp_total >= small.imp_total - 1e-9,
                            "{} < {} at t0={t0}",
                            big.imp_total,
                            small.imp_total
                        );
                    }
                    _ => {}
                }
            }
            Ok(())
        });
    }

    #[test]
    fn frontier_identical_to_per_budget_solves() {
        // the ISSUE acceptance bar: solve_frontier must return plans
        // BYTE-IDENTICAL to independent per-budget solves, for every
        // registered solver, on arbitrary (unsorted, duplicated) lists
        forall(25, 54, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let mut budgets: Vec<u64> =
                (0..(2 + rng.below(6))).map(|_| 5 + rng.below(140) as u64).collect();
            budgets.push(budgets[0]); // duplicate on purpose
            for (_, solver) in registry() {
                let swept = solver.solve_frontier(&inst.t, &inst, &budgets);
                crate::prop_assert!(
                    swept.len() == budgets.len(),
                    "{}: frontier arity {} != {}",
                    solver.name(),
                    swept.len(),
                    budgets.len()
                );
                for (n, &t0) in budgets.iter().enumerate() {
                    let fresh = solver.solve(&inst.t, &inst, t0);
                    if let Err(e) = same(&swept[n], &fresh) {
                        return Err(format!("{} at t0={t0}: {e}", solver.name()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_frontier_is_empty() {
        let mut rng = crate::util::rng::Rng::new(7);
        let inst = RandInstance::gen(&mut rng, 4);
        for (_, solver) in registry() {
            assert!(
                solver.solve_frontier(&inst.t, &inst, &[]).is_empty(),
                "{}",
                solver.name()
            );
        }
    }

    #[test]
    fn outcome_invariants() {
        forall(20, 55, |rng| {
            let l = 3 + rng.below(5);
            let inst = RandInstance::gen(rng, l);
            let t0 = 20 + rng.below(120) as u64;
            for (_, solver) in registry() {
                if let Some(out) = solver.solve(&inst.t, &inst, t0) {
                    for x in &out.a {
                        crate::prop_assert!(out.b.contains(x), "{}: A ⊄ B", solver.name());
                        crate::prop_assert!(out.s.contains(x), "{}: A ⊄ S", solver.name());
                    }
                    crate::prop_assert!(
                        out.est_ticks < t0,
                        "{}: budget violated",
                        solver.name()
                    );
                    // deleted spans: disjoint, ascending, and isolated
                    // as their own S-segments by kept_segments
                    let mut prev_end = 0usize;
                    for &(i, j) in &out.deleted {
                        crate::prop_assert!(
                            i >= prev_end && j > i && j <= l,
                            "{}: bad deleted span ({i}, {j}]",
                            solver.name()
                        );
                        prev_end = j;
                        crate::prop_assert!(
                            (i == 0 || out.s.contains(&i)) && (j == l || out.s.contains(&j)),
                            "{}: deleted span ({i}, {j}] not isolated in S={:?}",
                            solver.name(),
                            out.s
                        );
                    }
                    let kept = out.kept_segments(l);
                    crate::prop_assert!(
                        kept.len() + out.deleted.len()
                            == crate::merge::plan::segments_from_s(l, &out.s).len(),
                        "{}: kept + deleted != all segments",
                        solver.name()
                    );
                }
            }
            Ok(())
        });
    }

    // ---- budget edge-semantics regressions (pinned for all solvers) ----

    #[test]
    fn strict_budget_boundary_is_exclusive() {
        // one layer costing exactly 7 ticks: t0 = 7 must be infeasible
        // (strict <), t0 = 8 feasible — for every solver incl. brute
        let mut rng = crate::util::rng::Rng::new(11);
        let mut inst = RandInstance::gen(&mut rng, 1);
        inst.t.set(0, 1, 7);
        let all: Vec<(&'static str, Box<dyn Solver>)> = registry()
            .into_iter()
            .map(|(sp, s)| (sp.label(), s))
            .chain([
                ("brute-base", Box::new(BruteSolver { space: Space::Base }) as Box<dyn Solver>),
                ("brute-ext", Box::new(BruteSolver { space: Space::Extended })),
                ("brute-lm", Box::new(BruteSolver { space: Space::LayerMerge })),
            ])
            .collect();
        for (label, solver) in &all {
            let at = solver.solve(&inst.t, &inst, 7);
            match at {
                None => {}
                // layer-merge spaces may still delete the whole layer
                Some(ref out) if !out.deleted.is_empty() => {
                    assert_eq!(out.est_ticks, 0, "{label}")
                }
                Some(out) => panic!("{label}: latency {} accepted at t0=7", out.est_ticks),
            }
            let over = solver.solve(&inst.t, &inst, 8).unwrap_or_else(|| {
                panic!("{label}: t0=8 must fit the 7-tick plan");
            });
            assert!(over.est_ticks < 8, "{label}");
        }
    }

    #[test]
    fn empty_instance_feasible_iff_budget_positive() {
        // L = 0: latency is exactly 0; strict < t0 means t0 = 0 is
        // infeasible and t0 = 1 yields the empty plan — all solvers
        let mut rng = crate::util::rng::Rng::new(13);
        let inst = RandInstance::gen(&mut rng, 0);
        let mut all: Vec<Box<dyn Solver>> =
            registry().into_iter().map(|(_, s)| s).collect();
        all.push(Box::new(BruteSolver { space: Space::Base }));
        all.push(Box::new(BruteSolver { space: Space::Extended }));
        all.push(Box::new(BruteSolver { space: Space::LayerMerge }));
        for solver in &all {
            assert!(solver.solve(&inst.t, &inst, 0).is_none(), "{}", solver.name());
            let out = solver
                .solve(&inst.t, &inst, 1)
                .unwrap_or_else(|| panic!("{}: empty net infeasible at t0=1", solver.name()));
            assert_eq!(out.est_ticks, 0, "{}", solver.name());
            assert!(out.a.is_empty() && out.s.is_empty() && out.deleted.is_empty());
        }
    }

    #[test]
    fn singleton_instance_all_solvers_agree() {
        forall(10, 57, |rng| {
            let inst = RandInstance::gen(rng, 1);
            for t0 in [0u64, 1, 2, 40] {
                let oracle = BruteSolver { space: Space::LayerMerge }.solve(&inst.t, &inst, t0);
                let got = LayerMergeSolver.solve(&inst.t, &inst, t0);
                same_value(&got, &oracle, t0)?;
                let base_oracle = BruteSolver { space: Space::Base }.solve(&inst.t, &inst, t0);
                let base_got = TwoStageSolver.solve(&inst.t, &inst, t0);
                same_value(&base_got, &base_oracle, t0)?;
            }
            Ok(())
        });
    }

    #[test]
    fn layer_merge_plans_recheck_from_first_principles() {
        // objective re-derivable from (B, A, deleted) block by block,
        // latency re-derivable from kept segments — no DP involved
        forall(30, 58, |rng| {
            let l = 2 + rng.below(7);
            let inst = RandInstance::gen(rng, l);
            let t0 = 1 + rng.below(140) as u64;
            for solver in
                [&LayerMergeSolver as &dyn Solver, &ExtendedSolver as &dyn Solver]
            {
                if let Some(out) = solver.solve(&inst.t, &inst, t0) {
                    recheck_extended_family(&inst.t, &inst, &out, t0)
                        .map_err(|e| format!("{}: {e}", solver.name()))?;
                }
            }
            Ok(())
        });
    }
}
