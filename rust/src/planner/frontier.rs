//! The memoizing `Planner`: budget-independent DP products computed
//! once, every budget answered from them.
//!
//! The paper's headline figures are SWEEPS over the latency budget T0
//! (Fig. 3, Tables 1–2), yet stage 1 (Algorithm 1) and stage 3
//! (Algorithm 3) do not depend on T0 at all, and one stage-2/stage-4
//! table built at the largest budget already encodes the optimum for
//! every budget below it.  `Planner` owns those products per
//! (latency-table, importance) pair:
//!
//!   - `Stage1` is computed at construction and shared by both spaces;
//!   - `Stage3` is built lazily on the first extended-space solve;
//!   - the largest stage-2/stage-4 table built so far is kept, so a
//!     smaller budget never triggers a rebuild.
//!
//! `solve_frontier` therefore costs one table build + K extractions
//! instead of K independent solves, and returns plans identical to
//! per-budget `solve` calls (property-tested below and enforced at the
//! dp layer by the column-local table construction).

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use crate::dp::extended::{self, Stage3, Stage4Table};
use crate::dp::layer_merge::{self, LayerMergeTable};
use crate::dp::stage1::{self, LatTable, Stage1};
use crate::dp::stage2::{self, Stage2Table};
use crate::dp::stage2::NEG_INF;
use crate::importance::table::ImpTable;
use crate::model::spec::{ArchConfig, ACT_RELU6};
use crate::obs::metrics::Registry;
use crate::obs::span;

use super::solver::{ImportanceProvider, PlanOutcome};

/// Planner builds go to the process-wide registry (planners are
/// created deep inside the coordinator — threading a per-run registry
/// through every call path isn't worth it for build-shape telemetry):
/// `planner_memo_hit`/`planner_memo_miss` counters plus
/// `planner_build_ms` / `planner_build_cells` histograms.
fn note_build(t_build: Instant, cells: usize) {
    let reg = Registry::global();
    reg.counter_add("planner_memo_miss", 1);
    reg.observe("planner_build_ms", t_build.elapsed().as_secs_f64() * 1e3);
    reg.observe("planner_build_cells", cells as f64);
}

fn note_memo_hit() {
    Registry::global().counter_add("planner_memo_hit", 1);
}

/// Which solution space to plan in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Space {
    /// Algorithms 1+2 (B = A)
    Base,
    /// Algorithms 3+4 over (boundary, activation-state)
    Extended,
    /// the LayerMerge follow-up's joint (delete, linearize) space
    LayerMerge,
}

impl Space {
    /// The CLI/report label (`--solver` grammar, Pareto provenance).
    pub fn label(self) -> &'static str {
        match self {
            Space::Base => "twostage",
            Space::Extended => "extended",
            Space::LayerMerge => "layermerge",
        }
    }

    /// Parse a `--solver` token (aliases accepted, case-insensitive).
    pub fn parse(s: &str) -> Option<Space> {
        match s.to_ascii_lowercase().as_str() {
            "twostage" | "two-stage" | "base" => Some(Space::Base),
            "extended" | "ext" => Some(Space::Extended),
            "layermerge" | "layer-merge" | "lm" => Some(Space::LayerMerge),
            _ => None,
        }
    }

    /// Every space the CLI can ask for, in containment order.
    pub fn all() -> [Space; 3] {
        [Space::Base, Space::Extended, Space::LayerMerge]
    }
}

/// Budget-independent products memoized over a fixed (T, I) pair.
pub struct Planner<P: ImportanceProvider> {
    l: usize,
    s1: Stage1,
    imp: P,
    s3: RefCell<Option<Rc<Stage3>>>,
    base_tab: RefCell<Option<Rc<Stage2Table>>>,
    ext_tab: RefCell<Option<Rc<Stage4Table>>>,
    lm_tab: RefCell<Option<Rc<LayerMergeTable>>>,
}

impl<P: ImportanceProvider> Planner<P> {
    /// Runs Algorithm 1 eagerly (it is cheap and both spaces need it);
    /// everything else is built on demand.
    pub fn new(t: &LatTable, imp: P) -> Planner<P> {
        Planner {
            l: t.l,
            s1: stage1::solve(t),
            imp,
            s3: RefCell::new(None),
            base_tab: RefCell::new(None),
            ext_tab: RefCell::new(None),
            lm_tab: RefCell::new(None),
        }
    }

    pub fn l(&self) -> usize {
        self.l
    }

    /// The memoized Algorithm 1 product (optimal per-block latencies).
    pub fn stage1(&self) -> &Stage1 {
        &self.s1
    }

    pub fn importance(&self) -> &P {
        &self.imp
    }

    /// Memoized Algorithm 3 product (budget-independent).
    fn stage3(&self) -> Rc<Stage3> {
        if let Some(s3) = self.s3.borrow().as_ref() {
            return s3.clone();
        }
        let f = |i: usize, j: usize, a: u8, b: u8| self.imp.ext(i, j, a, b);
        let s3 = Rc::new(extended::solve_stage3(self.l, &f));
        *self.s3.borrow_mut() = Some(s3.clone());
        s3
    }

    /// Stage-2 table covering at least `t0` (kept; grows monotonically).
    fn base_table(&self, t0: u64) -> Rc<Stage2Table> {
        if let Some(tab) = self.base_tab.borrow().as_ref() {
            if tab.t0_max() >= t0 {
                note_memo_hit();
                return tab.clone();
            }
        }
        let _build_span = span::span_arg("plan", "build_stage2", t0 as i64);
        let t_build = Instant::now();
        let f = |i: usize, j: usize| self.imp.base(i, j);
        let tab = Rc::new(stage2::build(self.l, &self.s1, &f, t0));
        note_build(t_build, tab.cells());
        *self.base_tab.borrow_mut() = Some(tab.clone());
        tab
    }

    /// Stage-4 table covering at least `t0` (kept; grows monotonically).
    fn ext_table(&self, t0: u64) -> Rc<Stage4Table> {
        if let Some(tab) = self.ext_tab.borrow().as_ref() {
            if tab.t0_max() >= t0 {
                note_memo_hit();
                return tab.clone();
            }
        }
        let s3 = self.stage3();
        let _build_span = span::span_arg("plan", "build_stage4", t0 as i64);
        let t_build = Instant::now();
        let tab = Rc::new(extended::build(self.l, &self.s1, &s3, t0));
        note_build(t_build, tab.cells());
        *self.ext_tab.borrow_mut() = Some(tab.clone());
        tab
    }

    /// Layer-merge table covering at least `t0` (kept; grows
    /// monotonically).  Shares the stage-3 product with the extended
    /// space — switching spaces on one Planner never rebuilds it.
    fn lm_table(&self, t0: u64) -> Rc<LayerMergeTable> {
        if let Some(tab) = self.lm_tab.borrow().as_ref() {
            if tab.t0_max() >= t0 {
                note_memo_hit();
                return tab.clone();
            }
        }
        let s3 = self.stage3();
        let _build_span = span::span_arg("plan", "build_layer_merge", t0 as i64);
        let t_build = Instant::now();
        let d = |i: usize, j: usize, a: u8, b: u8| self.imp.del(i, j, a, b);
        let tab = Rc::new(layer_merge::build(self.l, &self.s1, &s3, &d, t0));
        note_build(t_build, tab.cells());
        *self.lm_tab.borrow_mut() = Some(tab.clone());
        tab
    }

    /// Jointly optimal plan under the strict integer budget `t0`.
    pub fn solve(&self, space: Space, t0: u64) -> Option<PlanOutcome> {
        match space {
            Space::Base => {
                let tab = self.base_table(t0);
                tab.extract(&self.s1, t0).map(|sol| PlanOutcome {
                    b: sol.a.clone(),
                    a: sol.a,
                    s: sol.s,
                    deleted: Vec::new(),
                    imp_total: sol.objective,
                    est_ticks: sol.latency,
                })
            }
            Space::Extended => {
                let s3 = self.stage3();
                let tab = self.ext_table(t0);
                tab.extract(&self.s1, &s3, t0).map(|sol| PlanOutcome {
                    a: sol.a,
                    b: sol.b,
                    s: sol.s,
                    deleted: Vec::new(),
                    imp_total: sol.objective,
                    est_ticks: sol.latency,
                })
            }
            Space::LayerMerge => {
                let s3 = self.stage3();
                let tab = self.lm_table(t0);
                tab.extract(&self.s1, &s3, t0).map(|sol| PlanOutcome {
                    a: sol.a,
                    b: sol.b,
                    s: sol.s,
                    deleted: sol.deleted,
                    imp_total: sol.objective,
                    est_ticks: sol.latency,
                })
            }
        }
    }

    /// Plans for every budget point (same order as `budgets`) from ONE
    /// DP table pass — identical to per-budget `solve` calls.
    ///
    /// ```
    /// use repro::dp::stage1::LatTable;
    /// use repro::planner::frontier::{Planner, Space};
    /// use repro::planner::solver::ImportanceProvider;
    ///
    /// // Two layers: keeping the boundary (no merge) scores importance
    /// // 1.0 per segment; merging (0,2] into one conv scores 0.0.
    /// struct Imp;
    /// impl ImportanceProvider for Imp {
    ///     fn base(&self, i: usize, j: usize) -> f64 {
    ///         if j == i + 1 { 1.0 } else { 0.0 }
    ///     }
    ///     fn ext(&self, i: usize, j: usize, _a: u8, _b: u8) -> f64 {
    ///         self.base(i, j)
    ///     }
    /// }
    ///
    /// // Integer tick latencies: each singleton costs 2, the merged
    /// // block costs 3.
    /// let mut t = LatTable::new(2);
    /// t.set(0, 1, 2);
    /// t.set(1, 2, 2);
    /// t.set(0, 2, 3);
    ///
    /// let planner = Planner::new(&t, Imp);
    /// // budgets are STRICT (latency < t0), like the dp layer
    /// let plans = planner.solve_frontier(Space::Base, &[4, 5]);
    /// // tight budget (t0 = 4: only latency 3 fits): forced to merge
    /// let tight = plans[0].as_ref().unwrap();
    /// assert_eq!(tight.s, Vec::<usize>::new());
    /// assert_eq!(tight.est_ticks, 3);
    /// // relaxed (t0 = 5): keep the boundary, win importance 2.0
    /// let relaxed = plans[1].as_ref().unwrap();
    /// assert_eq!(relaxed.s, vec![1]);
    /// assert_eq!(relaxed.est_ticks, 4);
    /// assert!(relaxed.imp_total > tight.imp_total);
    /// ```
    pub fn solve_frontier(&self, space: Space, budgets: &[u64]) -> Vec<Option<PlanOutcome>> {
        let Some(&t0_max) = budgets.iter().max() else {
            return Vec::new();
        };
        // one build at the largest budget; every extraction below hits it
        match space {
            Space::Base => {
                let _ = self.base_table(t0_max);
            }
            Space::Extended => {
                let _ = self.ext_table(t0_max);
            }
            Space::LayerMerge => {
                let _ = self.lm_table(t0_max);
            }
        }
        let _extract_span = span::span_arg("plan", "frontier_extract", budgets.len() as i64);
        let t_extract = Instant::now();
        let out: Vec<Option<PlanOutcome>> =
            budgets.iter().map(|&t0| self.solve(space, t0)).collect();
        Registry::global()
            .observe("planner_frontier_extract_ms", t_extract.elapsed().as_secs_f64() * 1e3);
        out
    }
}

/// `ImpTable` + the architecture's original activation states — the
/// coordinator-side `ImportanceProvider` (all solution spaces).
pub struct TableImportance {
    table: ImpTable,
    /// deletion-view importance for the layer-merge space; `None`
    /// means no span is deletable (del == NEG_INF everywhere)
    deletion: Option<ImpTable>,
    /// original endpoint state per boundary 0..=L (virtual ends "on")
    orig_on: Vec<bool>,
}

impl TableImportance {
    pub fn new(cfg: &ArchConfig, table: ImpTable) -> TableImportance {
        let l = cfg.spec.l();
        let mut orig_on = vec![true; l + 1];
        for x in 1..l {
            orig_on[x] = cfg.spec.layer(x).act == ACT_RELU6;
        }
        TableImportance { table, deletion: None, orig_on }
    }

    /// Attach a deletion view (layer-merge space); without one the
    /// layer-merge solver degenerates to the extended solver.
    pub fn with_deletion(cfg: &ArchConfig, table: ImpTable, deletion: ImpTable) -> TableImportance {
        let mut ti = TableImportance::new(cfg, table);
        ti.deletion = Some(deletion);
        ti
    }

    pub fn table(&self) -> &ImpTable {
        &self.table
    }

    pub fn deletion_table(&self) -> Option<&ImpTable> {
        self.deletion.as_ref()
    }
}

impl ImportanceProvider for TableImportance {
    fn base(&self, i: usize, j: usize) -> f64 {
        self.table.get(i, j, self.orig_on[i] as u8, self.orig_on[j] as u8)
    }

    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        self.table.get(i, j, a, b)
    }

    fn del(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        match &self.deletion {
            Some(d) => d.get(i, j, a, b),
            None => NEG_INF,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::proxy_importance;
    use crate::model::spec::testutil::tiny_config;
    use crate::planner::solver::{ExtendedSolver, LayerMergeSolver, Solver, TwoStageSolver};
    use crate::planner::testkit::RandInstance;
    use crate::util::prop::forall;

    fn same(
        a: &Option<PlanOutcome>,
        b: &Option<PlanOutcome>,
        what: &str,
    ) -> Result<(), String> {
        match (a, b) {
            (None, None) => Ok(()),
            (Some(x), Some(y))
                if x.a == y.a
                    && x.b == y.b
                    && x.s == y.s
                    && x.deleted == y.deleted
                    && x.est_ticks == y.est_ticks
                    && (x.imp_total - y.imp_total).abs() < 1e-9 =>
            {
                Ok(())
            }
            _ => Err(format!("{what}: {a:?} != {b:?}")),
        }
    }

    #[test]
    fn space_labels_round_trip() {
        for space in Space::all() {
            assert_eq!(Space::parse(space.label()), Some(space));
        }
        assert_eq!(Space::parse("base"), Some(Space::Base));
        assert_eq!(Space::parse("two-stage"), Some(Space::Base));
        assert_eq!(Space::parse("ext"), Some(Space::Extended));
        assert_eq!(Space::parse("layer-merge"), Some(Space::LayerMerge));
        assert_eq!(Space::parse("LayerMerge"), Some(Space::LayerMerge));
        assert_eq!(Space::parse("nope"), None);
    }

    #[test]
    fn planner_matches_stateless_solvers() {
        // the memoized path (shared stage-1/stage-3, grown tables) must
        // agree with a fresh solver run at every budget, in all spaces
        forall(25, 61, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let planner = Planner::new(&inst.t, &inst);
            // descending first, then ascending past the cached max —
            // exercises both the reuse and the rebuild paths
            for t0 in [120u64, 60, 20, 140, 7] {
                same(
                    &planner.solve(Space::Base, t0),
                    &TwoStageSolver.solve(&inst.t, &inst, t0),
                    "base",
                )?;
                same(
                    &planner.solve(Space::Extended, t0),
                    &ExtendedSolver.solve(&inst.t, &inst, t0),
                    "extended",
                )?;
                same(
                    &planner.solve(Space::LayerMerge, t0),
                    &LayerMergeSolver.solve(&inst.t, &inst, t0),
                    "layer-merge",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn planner_frontier_identical_to_per_budget() {
        forall(25, 62, |rng| {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(rng, l);
            let budgets: Vec<u64> =
                (0..(3 + rng.below(5))).map(|_| 5 + rng.below(140) as u64).collect();
            for space in Space::all() {
                let planner = Planner::new(&inst.t, &inst);
                let swept = planner.solve_frontier(space, &budgets);
                // fresh planner per budget = fully independent solves
                for (n, &t0) in budgets.iter().enumerate() {
                    let fresh = Planner::new(&inst.t, &inst).solve(space, t0);
                    same(&swept[n], &fresh, "frontier point")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn table_importance_matches_imp_base() {
        // the planner-side base view must reproduce ImpTable::imp_base
        // (original activation states, virtual endpoints on)
        let cfg = tiny_config();
        let imp = proxy_importance(&cfg);
        let ti = TableImportance::new(&cfg, imp.clone());
        for blk in &cfg.blocks {
            assert_eq!(
                ti.base(blk.i, blk.j),
                imp.imp_base(&cfg, blk.i, blk.j),
                "base view diverges at ({}, {}]",
                blk.i,
                blk.j
            );
        }
        for p in &cfg.probes {
            assert_eq!(ti.ext(p.i, p.j, p.a, p.b), imp.get(p.i, p.j, p.a, p.b));
        }
    }

    #[test]
    fn deletion_view_defaults_to_neg_inf() {
        // TableImportance without a deletion table must make the
        // layer-merge space collapse onto the extended space
        let cfg = tiny_config();
        let imp = proxy_importance(&cfg);
        let ti = TableImportance::new(&cfg, imp.clone());
        for p in &cfg.probes {
            assert_eq!(ti.del(p.i, p.j, p.a, p.b), crate::dp::stage2::NEG_INF);
        }
        let mut del = crate::importance::table::ImpTable::new(0.0, "deletion-test");
        del.insert(2, 3, 1, 1, -0.5);
        let ti2 = TableImportance::with_deletion(&cfg, imp, del);
        assert_eq!(ti2.del(2, 3, 1, 1), -0.5);
        assert_eq!(ti2.del(1, 2, 1, 1), crate::dp::stage2::NEG_INF);
        assert!(ti2.deletion_table().is_some());
    }

    #[test]
    fn planner_builds_and_memo_hits_reach_the_global_registry() {
        // global registry: other tests may be adding concurrently, so
        // pin deltas with >= on before/after snapshots
        let reg = Registry::global();
        let miss0 = reg.counter("planner_memo_miss");
        let hit0 = reg.counter("planner_memo_hit");
        let mut rng = crate::util::rng::Rng::new(0xAB);
        let inst = RandInstance::gen(&mut rng, 4);
        let planner = Planner::new(&inst.t, &inst);
        let _ = planner.solve(Space::Base, 60); // cold: build (miss)
        let _ = planner.solve(Space::Base, 30); // smaller budget: memo hit
        assert!(reg.counter("planner_memo_miss") >= miss0 + 1, "build not counted");
        assert!(reg.counter("planner_memo_hit") >= hit0 + 1, "memo hit not counted");
        let cells = reg.histogram("planner_build_cells").expect("build histogram");
        assert!(cells.count() >= 1);
        assert!(cells.max() >= 1.0, "stage-2 table has cells");
        assert!(reg.histogram("planner_build_ms").is_some());
    }

    #[test]
    fn objective_weakly_improves_with_budget() {
        forall(15, 63, |rng| {
            let l = 3 + rng.below(5);
            let inst = RandInstance::gen(rng, l);
            let planner = Planner::new(&inst.t, &inst);
            let budgets: Vec<u64> = vec![10, 30, 60, 120, 240];
            for space in Space::all() {
                let outs = planner.solve_frontier(space, &budgets);
                let mut prev = f64::NEG_INFINITY;
                for out in outs.into_iter().flatten() {
                    crate::prop_assert!(
                        out.imp_total >= prev - 1e-12,
                        "objective not monotone in budget"
                    );
                    prev = out.imp_total;
                }
            }
            Ok(())
        });
    }
}
