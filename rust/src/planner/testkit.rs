//! Shared seeded test harness for the solver family.
//!
//! One random-instance generator for every solver property test (the
//! ad-hoc generators previously copy-pasted across `dp/stage2.rs`,
//! `dp/extended.rs`, and `planner/solver.rs` all fold into
//! [`RandInstance`]), plus first-principles plan validators.  Compiled
//! unconditionally — not `#[cfg(test)]` — so benches (`bench_dp`) can
//! correctness-gate against the same instances before timing.

use crate::dp::stage1::{Cost, LatTable, INF};
use crate::dp::stage2::NEG_INF;
use crate::planner::solver::{ImportanceProvider, PlanOutcome};
use crate::util::rng::Rng;

/// Random dense importance over random merge-legal segments, with
/// probe-rule-shaped validity (mirrors specs.enumerate_probes):
/// interior boundaries whose original activation is relu6 cannot be
/// probed with that endpoint off, virtual endpoints are always on.
/// Carries all three importance views — `base`, `ext`, and a sparse
/// random deletion view `del` (layer-merge space) under the same
/// endpoint-state legality.
pub struct RandInstance {
    pub l: usize,
    pub t: LatTable,
    ext: Vec<f64>,
    del: Vec<f64>,
    pub orig_on: Vec<bool>,
}

impl RandInstance {
    pub fn gen(rng: &mut Rng, l: usize) -> RandInstance {
        let mut t = LatTable::new(l);
        let mut ext = vec![NEG_INF; (l + 1) * (l + 1) * 4];
        let mut del = vec![NEG_INF; (l + 1) * (l + 1) * 4];
        let mut orig_on = vec![true; l + 1];
        for x in 1..l {
            orig_on[x] = rng.uniform() < 0.5;
        }
        let legal = |i: usize, j: usize, a: u8, b: u8, orig_on: &[bool]| {
            !((i == 0 && a == 0)
                || (j == l && b == 0)
                || (i > 0 && orig_on[i] && a == 0)
                || (j < l && orig_on[j] && b == 0))
        };
        for i in 0..l {
            for j in i + 1..=l {
                let mergeable = j == i + 1 || rng.uniform() < 0.6;
                if mergeable {
                    t.set(i, j, 1 + rng.below(30) as u64);
                    for a in 0..2u8 {
                        for b in 0..2u8 {
                            if !legal(i, j, a, b, &orig_on) {
                                continue;
                            }
                            let v = -(rng.uniform() as f64) * (j - i) as f64
                                + 0.1 * (a as f64 + b as f64);
                            ext[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize] = v;
                        }
                    }
                }
                // deletion legality is independent of mergeability (an
                // identity needs no latency entry); usually costlier in
                // importance than keeping, but latency-free
                if rng.uniform() < 0.35 {
                    for a in 0..2u8 {
                        for b in 0..2u8 {
                            if !legal(i, j, a, b, &orig_on) {
                                continue;
                            }
                            let v = -(0.3 + 1.2 * rng.uniform() as f64) * (j - i) as f64
                                + 0.05 * (a as f64 + b as f64);
                            del[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize] = v;
                        }
                    }
                }
            }
        }
        RandInstance { l, t, ext, del, orig_on }
    }

    /// The base-space importance as the dense matrix shape the brute
    /// oracle (`brute::solve_base`) consumes.
    pub fn base_matrix(&self) -> Vec<Vec<f64>> {
        let mut m = vec![vec![NEG_INF; self.l + 1]; self.l + 1];
        for (i, row) in m.iter_mut().enumerate() {
            for (j, v) in row.iter_mut().enumerate().take(self.l + 1).skip(i + 1) {
                *v = self.base(i, j);
            }
        }
        m
    }
}

impl ImportanceProvider for RandInstance {
    fn base(&self, i: usize, j: usize) -> f64 {
        self.ext(i, j, self.orig_on[i] as u8, self.orig_on[j] as u8)
    }

    fn ext(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        self.ext[((i * (self.l + 1) + j) * 2 + a as usize) * 2 + b as usize]
    }

    fn del(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        self.del[((i * (self.l + 1) + j) * 2 + a as usize) * 2 + b as usize]
    }
}

/// Random integer latency table alone (stage-1-level tests): singleton
/// segments always present, longer merges with probability `merge_p`.
pub fn rand_lat_table(rng: &mut Rng, l: usize, merge_p: f32) -> LatTable {
    let mut t = LatTable::new(l);
    for i in 0..l {
        for j in i + 1..=l {
            if j == i + 1 {
                t.set(i, j, 1 + rng.below(50) as Cost);
            } else if rng.uniform() < merge_p {
                t.set(i, j, 1 + rng.below(100) as Cost);
            }
        }
    }
    t
}

/// Re-derive a plan's objective and latency from first principles — no
/// DP tables involved — and check them against the `PlanOutcome`
/// fields and the strict budget.  Valid for the EXTENDED-family
/// solvers (`ExtendedSolver`, `LayerMergeSolver`), where membership in
/// A means "boundary state 1": the objective is the sum of `ext` (or
/// `del` for deleted spans) over the consecutive blocks of
/// {0} ∪ B ∪ {L}, and the latency is the sum of the raw `LatTable`
/// entries over the kept S-segments (each is exactly one merged conv).
pub fn recheck_extended_family(
    t: &LatTable,
    imp: &dyn ImportanceProvider,
    out: &PlanOutcome,
    t0: u64,
) -> Result<(), String> {
    let l = t.l;
    let state = |x: usize| -> u8 {
        if x == 0 || x == l || out.a.contains(&x) {
            1
        } else {
            0
        }
    };
    let mut pts = vec![0usize];
    pts.extend(out.b.iter().copied().filter(|&x| x > 0 && x < l));
    pts.push(l);
    pts.sort_unstable();
    pts.dedup();
    let mut obj = 0.0;
    for w in pts.windows(2) {
        let (i, j) = (w[0], w[1]);
        let v = if out.deleted.contains(&(i, j)) {
            imp.del(i, j, state(i), state(j))
        } else {
            imp.ext(i, j, state(i), state(j))
        };
        if v == NEG_INF {
            return Err(format!("block ({i}, {j}] has invalid importance in plan {out:?}"));
        }
        obj += v;
    }
    if (obj - out.imp_total).abs() > 1e-6 {
        return Err(format!("recomputed objective {obj} != imp_total {}", out.imp_total));
    }
    let mut lat: u64 = 0;
    for (u, v) in out.kept_segments(l) {
        let c = t.get(u, v);
        if c >= INF {
            return Err(format!("kept segment ({u}, {v}] is not merge-legal"));
        }
        lat += c;
    }
    if lat != out.est_ticks {
        return Err(format!("recomputed latency {lat} != est_ticks {}", out.est_ticks));
    }
    if lat >= t0 {
        return Err(format!("latency {lat} violates strict budget {t0}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_respects_probe_rules() {
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let l = 2 + rng.below(6);
            let inst = RandInstance::gen(&mut rng, l);
            for i in 0..l {
                for j in i + 1..=l {
                    for (a, b) in [(0u8, 0u8), (0, 1), (1, 0), (1, 1)] {
                        let illegal = (i == 0 && a == 0)
                            || (j == l && b == 0)
                            || (i > 0 && inst.orig_on[i] && a == 0)
                            || (j < l && inst.orig_on[j] && b == 0);
                        if illegal {
                            assert_eq!(inst.ext(i, j, a, b), NEG_INF);
                            assert_eq!(
                                ImportanceProvider::del(&inst, i, j, a, b),
                                NEG_INF
                            );
                        }
                    }
                }
            }
            // singleton segments always merge-legal
            for i in 0..l {
                assert!(inst.t.get(i, i + 1) < INF);
            }
        }
    }

    #[test]
    fn base_matrix_matches_base_view() {
        let mut rng = Rng::new(100);
        let inst = RandInstance::gen(&mut rng, 5);
        let m = inst.base_matrix();
        for i in 0..5 {
            for j in i + 1..=5 {
                assert_eq!(m[i][j], inst.base(i, j));
            }
        }
    }

    #[test]
    fn recheck_accepts_a_hand_built_plan() {
        // 2 layers, both kept unmerged, boundary 1 active
        let mut t = LatTable::new(2);
        t.set(0, 1, 3);
        t.set(1, 2, 4);
        struct Fixed;
        impl ImportanceProvider for Fixed {
            fn base(&self, i: usize, j: usize) -> f64 {
                self.ext(i, j, 1, 1)
            }
            fn ext(&self, i: usize, j: usize, _a: u8, _b: u8) -> f64 {
                if j == i + 1 {
                    -0.25
                } else {
                    NEG_INF
                }
            }
        }
        let out = PlanOutcome {
            a: vec![1],
            b: vec![1],
            s: vec![1],
            deleted: Vec::new(),
            imp_total: -0.5,
            est_ticks: 7,
        };
        recheck_extended_family(&t, &Fixed, &out, 8).unwrap();
        // and rejects a budget violation
        assert!(recheck_extended_family(&t, &Fixed, &out, 7).is_err());
        // and a wrong objective
        let mut bad = out.clone();
        bad.imp_total = -0.4;
        assert!(recheck_extended_family(&t, &Fixed, &bad, 8).is_err());
    }
}
