//! Channel-pruning baselines (paper Appendix C.3, Table 8): uniform-L1,
//! AMC-ratio, MetaPruning-ratio.
//!
//! The pruned architectures (smaller hidden dims per IRB) are emitted by
//! python (`specs.mbv2_micro_pruned`) with their own AOT artifacts; this
//! module does the weight *selection*: which channels of the pretrained
//! base network survive, by L1-norm of the expand conv's output
//! channels (Li et al., 2017), mapped into the pruned net's parameters.

use anyhow::{bail, Result};

use crate::model::spec::NetworkSpec;
use crate::tensor::Tensor;
use crate::trainer::params::ParamSet;

/// Top-k channel indices of `w` (OIHW) by L1 norm of each output slice.
pub fn topk_channels_by_l1(w: &Tensor, k: usize) -> Vec<usize> {
    let co = w.shape[0];
    let per = w.len() / co;
    let mut scored: Vec<(usize, f32)> = (0..co)
        .map(|o| {
            let s: f32 = w.data[o * per..(o + 1) * per].iter().map(|x| x.abs()).sum();
            (o, s)
        })
        .collect();
    scored.sort_by(|a, b| b.1.total_cmp(&a.1));
    let mut idx: Vec<usize> = scored[..k].iter().map(|&(o, _)| o).collect();
    idx.sort_unstable();
    idx
}

fn slice_rows(w: &Tensor, rows: &[usize]) -> Tensor {
    let per = w.len() / w.shape[0];
    let mut shape = w.shape.clone();
    shape[0] = rows.len();
    let mut out = Tensor::zeros(&shape);
    for (n, &r) in rows.iter().enumerate() {
        out.data[n * per..(n + 1) * per].copy_from_slice(&w.data[r * per..(r + 1) * per]);
    }
    out
}

fn slice_cols(w: &Tensor, cols: &[usize]) -> Tensor {
    // OIHW: slice the I dim
    let (o, _i, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let mut out = Tensor::zeros(&[o, cols.len(), kh, kw]);
    for oo in 0..o {
        for (n, &c) in cols.iter().enumerate() {
            for y in 0..kh {
                for x in 0..kw {
                    *out.at4_mut(oo, n, y, x) = w.at4(oo, c, y, x);
                }
            }
        }
    }
    out
}

fn slice_vec(v: &Tensor, idx: &[usize]) -> Tensor {
    Tensor::from_vec(&[idx.len()], idx.iter().map(|&i| v.data[i]).collect()).unwrap()
}

/// Map pretrained base-network parameters into a pruned architecture.
///
/// For each layer whose c_out shrank, the kept channels are the top-k by
/// L1 norm of the base conv weight; dependent dims (the next layer's
/// c_in, depthwise groups, BN vectors) follow the same index set.
pub fn prune_params(
    base: &NetworkSpec,
    pruned: &NetworkSpec,
    ps: &ParamSet,
) -> Result<ParamSet> {
    if base.l() != pruned.l() {
        bail!("layer count mismatch");
    }
    let mut out = ParamSet::new();
    // kept output-channel indices per layer (None = all kept)
    let mut kept: Vec<Option<Vec<usize>>> = vec![None; base.l() + 1];
    for l in 1..=base.l() {
        let lb = base.layer(l);
        let lp = pruned.layer(l);
        let w = ps.get(&format!("w{l}"))?;
        // input mapping from the previous layer
        let in_map = if l > 1 { kept[l - 1].clone() } else { None };
        let mut wl = w.clone();
        if lb.is_depthwise() {
            // depthwise: out channels == in channels; follow the in map
            if let Some(map) = &in_map {
                if lp.c_out != map.len() {
                    bail!("dw layer {l}: pruned c_out {} != kept {}", lp.c_out, map.len());
                }
                wl = slice_rows(&wl, map);
                kept[l] = Some(map.clone());
            } else {
                kept[l] = None;
            }
        } else {
            if let Some(map) = &in_map {
                wl = slice_cols(&wl, map);
            }
            if lp.c_out < lb.c_out {
                let rows = topk_channels_by_l1(w, lp.c_out);
                wl = slice_rows(&wl, &rows);
                kept[l] = Some(rows);
            } else {
                kept[l] = None;
            }
        }
        out.insert(format!("w{l}"), wl);
        // BN params follow the output-channel map
        for nm in ["gamma", "beta", "mean", "var"] {
            let v = ps.get(&format!("{nm}{l}"))?;
            let sliced = match &kept[l] {
                Some(map) => slice_vec(v, map),
                None => v.clone(),
            };
            out.insert(format!("{nm}{l}"), sliced);
        }
    }
    // classifier: input dim follows the last layer's map
    let fc_w = ps.get("fc_w")?;
    let fc = match &kept[base.l()] {
        Some(map) => {
            let (ci, nc) = (fc_w.shape[0], fc_w.shape[1]);
            let mut t = Tensor::zeros(&[map.len(), nc]);
            for (n, &r) in map.iter().enumerate() {
                t.data[n * nc..(n + 1) * nc]
                    .copy_from_slice(&fc_w.data[r * nc..(r + 1) * nc]);
            }
            let _ = ci;
            t
        }
        None => fc_w.clone(),
    };
    out.insert("fc_w".into(), fc);
    out.insert("fc_b".into(), ps.get("fc_b")?.clone());
    // validate against the pruned spec
    for l in 1..=pruned.l() {
        let lp = pruned.layer(l);
        let w = out.get(&format!("w{l}"))?;
        let want = vec![lp.c_out, lp.c_in / lp.groups, lp.k, lp.k];
        if w.shape != want {
            bail!("layer {l}: pruned weight shape {:?} != spec {:?}", w.shape, want);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn topk_picks_largest_l1() {
        let w = Tensor::from_vec(
            &[3, 1, 1, 2],
            vec![0.1, 0.1, 5.0, 5.0, 1.0, -3.0],
        )
        .unwrap();
        assert_eq!(topk_channels_by_l1(&w, 2), vec![1, 2]);
        assert_eq!(topk_channels_by_l1(&w, 1), vec![1]);
    }

    #[test]
    fn slicing_keeps_values() {
        let mut rng = Rng::new(1);
        let mut w = Tensor::zeros(&[4, 3, 1, 1]);
        for v in w.data.iter_mut() {
            *v = rng.normal();
        }
        let r = slice_rows(&w, &[1, 3]);
        assert_eq!(r.shape, vec![2, 3, 1, 1]);
        assert_eq!(r.at4(0, 2, 0, 0), w.at4(1, 2, 0, 0));
        let c = slice_cols(&w, &[0, 2]);
        assert_eq!(c.shape, vec![4, 2, 1, 1]);
        assert_eq!(c.at4(3, 1, 0, 0), w.at4(3, 2, 0, 0));
    }
}
