//! DepthShrinker baseline (Fu et al., ICML 2022) — the paper's main
//! comparison.
//!
//! DS's search space is strictly smaller than ours: it only removes the
//! activations INSIDE one inverted residual block and merges that block
//! into a single dense conv — it can never merge across block
//! boundaries (paper Figure 4).  We reproduce it inside our (A, S)
//! framework: a DS pattern deactivates k IRBs; kept layers stay
//! unmerged singletons.
//!
//! The DS search phase trains per-activation gates jointly; our analog
//! ranks IRBs by the measured importance of deactivating each block
//! (same ImpTable the DP consumes), which reproduces its selection
//! behaviour without a second training system (App. C.1 reproduction).

use anyhow::{bail, Result};

use crate::importance::table::ImpTable;
use crate::model::spec::{ArchConfig, ACT_RELU6};

/// The layer span (i, j] of an IRB's mergeable body.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IrbSpan {
    pub irb: usize,
    pub i: usize,
    pub j: usize,
}

/// Enumerate IRB body spans that are merge-legal as one block.
pub fn irb_spans(cfg: &ArchConfig) -> Vec<IrbSpan> {
    let mut spans = Vec::new();
    let l = cfg.spec.l();
    let mut cur: Option<(usize, usize, usize)> = None; // (irb, first, last)
    for ly in &cfg.spec.layers {
        let Some(irb) = ly.irb else { continue };
        match cur {
            Some((b, first, last)) if b == irb => cur = Some((b, first, last.max(ly.idx))),
            Some((b, first, last)) => {
                spans.push((b, first, last));
                cur = Some((irb, ly.idx, ly.idx));
                let _ = (b, first, last);
            }
            None => cur = Some((irb, ly.idx, ly.idx)),
        }
    }
    if let Some((b, first, last)) = cur {
        spans.push((b, first, last));
    }
    spans
        .into_iter()
        .filter(|&(_, first, last)| first < last) // need >= 2 layers to merge
        .map(|(irb, first, last)| IrbSpan { irb, i: first - 1, j: last })
        .filter(|s| s.j <= l && cfg.mergeable(s.i, s.j))
        .collect()
}

/// A DS compression pattern: which IRBs are deactivated+merged.
#[derive(Debug, Clone)]
pub struct DsPattern {
    pub name: String,
    pub deactivated: Vec<IrbSpan>,
    pub a: Vec<usize>,
    pub s: Vec<usize>,
}

/// Build the (A, S) sets for a set of deactivated IRB spans.
///
/// A = original relu6 positions outside deactivated bodies;
/// S = all interior boundaries except inside deactivated bodies.
pub fn ds_pattern(cfg: &ArchConfig, name: &str, deact: &[IrbSpan]) -> Result<DsPattern> {
    let l = cfg.spec.l();
    for s in deact {
        if !cfg.mergeable(s.i, s.j) {
            bail!("IRB span ({}, {}] is not mergeable", s.i, s.j);
        }
    }
    let interior = |x: usize| deact.iter().any(|s| x > s.i && x < s.j);
    let mut a = Vec::new();
    let mut s_set = Vec::new();
    for b in 1..l {
        if interior(b) {
            continue;
        }
        s_set.push(b);
        if cfg.spec.layer(b).act == ACT_RELU6 {
            a.push(b);
        }
    }
    Ok(DsPattern { name: name.to_string(), deactivated: deact.to_vec(), a, s: s_set })
}

/// Importance of deactivating a whole IRB body (endpoints at original
/// states), from the same table the DP uses.
pub fn irb_importance(cfg: &ArchConfig, imp: &ImpTable, span: &IrbSpan) -> f64 {
    imp.imp_base(cfg, span.i, span.j)
}

/// Reproduced DS search (App. C.1): keep the `k_active` most damaging
/// blocks activated, deactivate the rest — i.e. deactivate the
/// `n - k_active` blocks with the LEAST accuracy damage.
pub fn ds_search(
    cfg: &ArchConfig,
    imp: &ImpTable,
    k_active: usize,
    name: &str,
) -> Result<DsPattern> {
    let mut spans = irb_spans(cfg);
    if spans.is_empty() {
        bail!("architecture has no mergeable IRB bodies");
    }
    if k_active > spans.len() {
        bail!("k_active {} > {} mergeable IRBs", k_active, spans.len());
    }
    // least damage (highest importance) deactivated first
    spans.sort_by(|x, y| irb_importance(cfg, imp, y).total_cmp(&irb_importance(cfg, imp, x)));
    let deact: Vec<IrbSpan> = spans[..spans.len() - k_active].to_vec();
    ds_pattern(cfg, name, &deact)
}

/// The fixed DS-A..E compression ladder, scaled to this architecture:
/// progressively fewer active IRBs (paper used 12/9/7 of 17 on MBV2;
/// we sweep the same fractions of our IRB count).
pub fn ds_ladder(cfg: &ArchConfig, imp: &ImpTable) -> Result<Vec<DsPattern>> {
    let n = irb_spans(cfg).len();
    let fracs = [0.75, 0.6, 0.45, 0.3, 0.15];
    let names = ["DS-A", "DS-B", "DS-C", "DS-D", "DS-E"];
    let mut out = Vec::new();
    let mut seen = Vec::new();
    for (f, name) in fracs.iter().zip(names) {
        let k = ((n as f64) * f).round() as usize;
        if seen.contains(&k) {
            continue;
        }
        seen.push(k);
        out.push(ds_search(cfg, imp, k, name)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::testutil::tiny_config;

    fn fake_imp(cfg: &ArchConfig) -> ImpTable {
        let mut t = ImpTable::new(0.8, "fake");
        for blk in &cfg.blocks {
            let a = if blk.i == 0 || cfg.spec.layer(blk.i).act == ACT_RELU6 { 1 } else { 0 };
            let b = if blk.j == cfg.spec.l() || cfg.spec.layer(blk.j).act == ACT_RELU6 {
                1
            } else {
                0
            };
            // bigger blocks hurt more
            t.insert(blk.i, blk.j, a, b, -0.01 * (blk.j - blk.i) as f64);
        }
        t
    }

    #[test]
    fn spans_cover_mergeable_irbs() {
        let cfg = tiny_config();
        let spans = irb_spans(&cfg);
        // tiny net: IRB1 body (1,4] is mergeable; IRB2 (4,6] is mergeable
        assert!(spans.contains(&IrbSpan { irb: 1, i: 1, j: 4 }));
        assert!(spans.contains(&IrbSpan { irb: 2, i: 4, j: 6 }));
    }

    #[test]
    fn pattern_builds_a_and_s() {
        let cfg = tiny_config();
        let spans = irb_spans(&cfg);
        let p = ds_pattern(&cfg, "DS-X", &spans[..1]).unwrap();
        // deactivated body (1,4]: boundaries 2,3 removed from S
        assert!(!p.s.contains(&2) && !p.s.contains(&3));
        assert!(p.s.contains(&1) && p.s.contains(&4) && p.s.contains(&5));
        // A = relu6 positions outside the body
        assert!(p.a.contains(&1) && p.a.contains(&5));
        assert!(!p.a.contains(&2));
    }

    #[test]
    fn search_deactivates_least_damaging() {
        let cfg = tiny_config();
        let mut imp = fake_imp(&cfg);
        // make IRB2 (4,6] nearly free to remove
        imp.insert(4, 6, 1, 1, -0.001);
        let p = ds_search(&cfg, &imp, 1, "DS-T").unwrap();
        assert_eq!(p.deactivated.len(), 1);
        assert_eq!((p.deactivated[0].i, p.deactivated[0].j), (4, 6));
    }

    #[test]
    fn ds_cannot_merge_across_blocks() {
        // structural assertion of the Figure-4 contrast: every DS merge
        // segment lies within one IRB
        let cfg = tiny_config();
        let imp = fake_imp(&cfg);
        for p in ds_ladder(&cfg, &imp).unwrap() {
            for span in &p.deactivated {
                let irbs: std::collections::BTreeSet<_> = (span.i + 1..=span.j)
                    .map(|l| cfg.spec.layer(l).irb)
                    .collect();
                assert_eq!(irbs.len(), 1, "DS merged across IRBs: {:?}", span);
            }
        }
    }
}
