//! Stage-1 DP (paper Algorithm 1): optimal merge pattern and latency
//! for every contiguous block.
//!
//!   T_opt[k, l] = min_{S subset of (k, l)} sum of T over the segments
//!   S_opt[k, l] = the argmin split set
//!
//! `T[i][j]` is the integer-scaled latency of merging layers i+1..j into
//! ONE convolution (INF if the segment is not merge-legal).  O(L^3).

/// Integer latency cost; INF marks non-mergeable segments.
pub type Cost = u64;
pub const INF: Cost = u64::MAX / 4;

/// Dense upper-triangular latency table T[i][j] for 0 <= i < j <= L.
#[derive(Debug, Clone)]
pub struct LatTable {
    pub l: usize,
    /// flattened (L+1) x (L+1); entry [i][j] valid for i < j
    t: Vec<Cost>,
}

impl LatTable {
    pub fn new(l: usize) -> LatTable {
        LatTable { l, t: vec![INF; (l + 1) * (l + 1)] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> Cost {
        self.t[i * (self.l + 1) + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: Cost) {
        assert!(i < j && j <= self.l, "bad segment ({i},{j}]");
        self.t[i * (self.l + 1) + j] = v;
    }
}

/// Output of Algorithm 1: optimal block latencies + parent pointers.
#[derive(Debug, Clone)]
pub struct Stage1 {
    pub l: usize,
    t_opt: Vec<Cost>,
    /// split[k][l] = m: last segment is (m, l]; m == k means single merge
    split: Vec<usize>,
}

impl Stage1 {
    #[inline]
    pub fn t_opt(&self, k: usize, l: usize) -> Cost {
        self.t_opt[k * (self.l + 1) + l]
    }

    /// Reconstruct S_opt[k, l] (interior split points, ascending).
    pub fn s_opt(&self, k: usize, l: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut hi = l;
        while hi > k {
            let m = self.split[k * (self.l + 1) + hi];
            if m == k {
                break;
            }
            out.push(m);
            hi = m;
        }
        out.reverse();
        out
    }

    pub fn feasible(&self, k: usize, l: usize) -> bool {
        self.t_opt(k, l) < INF
    }
}

/// Algorithm 1.  T must have finite entries for all singleton segments
/// (every layer can always stand alone).
pub fn solve(t: &LatTable) -> Stage1 {
    let l_total = t.l;
    let n = l_total + 1;
    let mut t_opt = vec![0 as Cost; n * n];
    let mut split = vec![0usize; n * n];
    for l in 1..=l_total {
        for k in (0..l).rev() {
            // m' = k means "merge (k, l] as a single conv"
            let mut best = t.get(k, l);
            let mut best_m = k;
            for m in k + 1..l {
                let cand = t_opt[k * n + m].saturating_add(t.get(m, l));
                if cand < best {
                    best = cand;
                    best_m = m;
                }
            }
            t_opt[k * n + l] = best;
            split[k * n + l] = best_m;
        }
    }
    Stage1 { l: l_total, t_opt, split }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_table(rng: &mut Rng, l: usize, merge_p: f32) -> LatTable {
        let mut t = LatTable::new(l);
        for i in 0..l {
            for j in i + 1..=l {
                if j == i + 1 {
                    t.set(i, j, 1 + rng.below(50) as Cost);
                } else if rng.uniform() < merge_p {
                    t.set(i, j, 1 + rng.below(100) as Cost);
                }
            }
        }
        t
    }

    /// Brute-force min over all partitions of (k, l].
    fn brute_min(t: &LatTable, k: usize, l: usize) -> Cost {
        if k == l {
            return 0;
        }
        let mut best = INF;
        for m in k..l {
            let head = if m == k { 0 } else { brute_min(t, k, m) };
            let seg = t.get(m, l);
            if head < INF && seg < INF {
                best = best.min(head + seg);
            }
        }
        best
    }

    #[test]
    fn matches_brute_force() {
        forall(30, 21, |rng| {
            let l = 3 + rng.below(6);
            let t = random_table(rng, l, 0.5);
            let s1 = solve(&t);
            for k in 0..l {
                for j in k + 1..=l {
                    let want = brute_min(&t, k, j);
                    crate::prop_assert!(
                        s1.t_opt(k, j) == want,
                        "T_opt[{k},{j}] = {} != brute {}",
                        s1.t_opt(k, j),
                        want
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn s_opt_reconstruction_consistent() {
        forall(30, 22, |rng| {
            let l = 3 + rng.below(6);
            let t = random_table(rng, l, 0.4);
            let s1 = solve(&t);
            for k in 0..l {
                for j in k + 1..=l {
                    if !s1.feasible(k, j) {
                        continue;
                    }
                    let s = s1.s_opt(k, j);
                    // segments implied by S must sum to T_opt
                    let mut pts = vec![k];
                    pts.extend(&s);
                    pts.push(j);
                    let mut total: Cost = 0;
                    for w in pts.windows(2) {
                        crate::prop_assert!(
                            t.get(w[0], w[1]) < INF,
                            "S_opt contains illegal segment ({}, {}]",
                            w[0],
                            w[1]
                        );
                        total += t.get(w[0], w[1]);
                    }
                    crate::prop_assert!(
                        total == s1.t_opt(k, j),
                        "S_opt[{k},{j}] sums to {total} != {}",
                        s1.t_opt(k, j)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prefers_single_merge_when_cheaper() {
        let mut t = LatTable::new(3);
        t.set(0, 1, 10);
        t.set(1, 2, 10);
        t.set(2, 3, 10);
        t.set(0, 2, 5);
        t.set(0, 3, 4);
        t.set(1, 3, 5);
        let s1 = solve(&t);
        assert_eq!(s1.t_opt(0, 3), 4);
        assert!(s1.s_opt(0, 3).is_empty());
    }

    #[test]
    fn splits_when_merge_hurts() {
        // the paper's 100->1->100 pointwise example: merging explodes cost
        let mut t = LatTable::new(2);
        t.set(0, 1, 3);
        t.set(1, 2, 3);
        t.set(0, 2, 1000);
        let s1 = solve(&t);
        assert_eq!(s1.t_opt(0, 2), 6);
        assert_eq!(s1.s_opt(0, 2), vec![1]);
    }

    #[test]
    fn base_cases() {
        let mut t = LatTable::new(1);
        t.set(0, 1, 7);
        let s1 = solve(&t);
        assert_eq!(s1.t_opt(0, 0), 0);
        assert_eq!(s1.t_opt(0, 1), 7);
        assert!(s1.s_opt(0, 1).is_empty());
    }
}
