//! Brute-force oracles for the DP solvers (tests only, exponential).
//!
//! Enumerate every A (and (A, B) in the extended space) directly from
//! the problem definition (paper Eq. 6 / Eq. 16) — no recurrences — so
//! agreement with dp/stage2.rs and dp/extended.rs is real evidence of
//! Propositions 4.1 / 4.2.

use super::layer_merge::LmSolution;
use super::stage1::{LatTable, Stage1};
use super::stage2::{Solution, NEG_INF};

/// Base space: maximize sum I over the A-partition subject to
/// sum T_opt over A-segments < t0.  imp[i][j] = NEG_INF marks invalid.
pub fn solve_base(
    l_total: usize,
    t: &LatTable,
    imp: &[Vec<f64>],
    t0: u64,
) -> Option<Solution> {
    if l_total == 0 {
        // empty network: latency exactly 0, feasible iff 0 < t0 (the
        // generic window loop below would read imp[0][0] = NEG_INF)
        return (t0 >= 1).then(|| Solution {
            a: Vec::new(),
            s: Vec::new(),
            objective: 0.0,
            latency: 0,
        });
    }
    let s1 = super::stage1::solve(t);
    let mut best: Option<Solution> = None;
    // enumerate subsets A of [1, L-1]
    let m = l_total.saturating_sub(1);
    for bits in 0..(1u32 << m) {
        let mut a = Vec::new();
        for p in 0..m {
            if bits & (1 << p) != 0 {
                a.push(p + 1);
            }
        }
        let mut pts = vec![0usize];
        pts.extend(&a);
        pts.push(l_total);
        let mut obj = 0.0;
        let mut lat: u64 = 0;
        let mut ok = true;
        for w in pts.windows(2) {
            let v = imp[w[0]][w[1]];
            if v == NEG_INF || !s1.feasible(w[0], w[1]) {
                ok = false;
                break;
            }
            obj += v;
            lat = lat.saturating_add(s1.t_opt(w[0], w[1]));
        }
        if !ok || lat >= t0 {
            continue;
        }
        if best.as_ref().map_or(true, |b| obj > b.objective) {
            let mut s = a.clone();
            for w in pts.windows(2) {
                s.extend(s1.s_opt(w[0], w[1]));
            }
            s.sort_unstable();
            s.dedup();
            best = Some(Solution { a, s, objective: obj, latency: lat });
        }
    }
    best
}

/// Extended space (Appendix B.1): maximize I(A, B) over A subset of B,
/// where imp4[i][j][a][b] carries the (d_i, d_j)-indexed importances.
/// Returns (A, B, S, objective, latency).
pub struct ExtSolution {
    pub a: Vec<usize>,
    pub b: Vec<usize>,
    pub s: Vec<usize>,
    pub objective: f64,
    pub latency: u64,
}

pub fn solve_extended(
    l_total: usize,
    t: &LatTable,
    imp4: &dyn Fn(usize, usize, u8, u8) -> f64,
    t0: u64,
) -> Option<ExtSolution> {
    if l_total == 0 {
        return (t0 >= 1).then(|| ExtSolution {
            a: Vec::new(),
            b: Vec::new(),
            s: Vec::new(),
            objective: 0.0,
            latency: 0,
        });
    }
    let s1: Stage1 = super::stage1::solve(t);
    let m = l_total.saturating_sub(1);
    let mut best: Option<ExtSolution> = None;
    for b_bits in 0..(1u32 << m) {
        let mut b_set = Vec::new();
        for p in 0..m {
            if b_bits & (1 << p) != 0 {
                b_set.push(p + 1);
            }
        }
        let mut pts = vec![0usize];
        pts.extend(&b_set);
        pts.push(l_total);
        // enumerate A subset of B via per-boundary activation bits
        let nb = b_set.len();
        for a_bits in 0..(1u32 << nb) {
            let state = |bound: usize| -> u8 {
                if bound == 0 || bound == l_total {
                    1
                } else {
                    let pos = b_set.iter().position(|&x| x == bound).unwrap();
                    ((a_bits >> pos) & 1) as u8
                }
            };
            let mut obj = 0.0;
            let mut ok = true;
            for w in pts.windows(2) {
                let v = imp4(w[0], w[1], state(w[0]), state(w[1]));
                if v == NEG_INF {
                    ok = false;
                    break;
                }
                obj += v;
            }
            if !ok {
                continue;
            }
            // merging may cross id joints (state-0 boundaries): the
            // latency-optimal S splits only at state-1 (= A) positions
            let a: Vec<usize> = b_set
                .iter()
                .enumerate()
                .filter(|(p, _)| a_bits & (1 << p) != 0)
                .map(|(_, &x)| x)
                .collect();
            let mut apts = vec![0usize];
            apts.extend(&a);
            apts.push(l_total);
            let mut lat: u64 = 0;
            let mut feasible = true;
            for w in apts.windows(2) {
                if !s1.feasible(w[0], w[1]) {
                    feasible = false;
                    break;
                }
                lat = lat.saturating_add(s1.t_opt(w[0], w[1]));
            }
            if !feasible || lat >= t0 {
                continue;
            }
            if best.as_ref().map_or(true, |bb| obj > bb.objective) {
                let mut s = a.clone();
                for w in apts.windows(2) {
                    s.extend(s1.s_opt(w[0], w[1]));
                }
                s.sort_unstable();
                s.dedup();
                best = Some(ExtSolution {
                    a,
                    b: b_set.clone(),
                    s,
                    objective: obj,
                    latency: lat,
                });
            }
        }
    }
    best
}

/// Layer-merge space (LayerMerge follow-up): enumerate every block
/// structure B, every activation assignment A subset of B, AND a
/// keep/delete mode per block.  Kept blocks score `imp4`, deleted
/// blocks score `del` (NEG_INF = deletion illegal there).  Latency is
/// summed over BARRIER intervals — barriers are {0, L}, state-1
/// boundaries, and every deleted-block endpoint (a merged convolution
/// cannot span a hole) — with deleted intervals contributing zero
/// ticks and kept intervals T_opt.  Exponential (~5^L configs): tests
/// only, small L.
pub fn solve_layer_merge(
    l_total: usize,
    t: &LatTable,
    imp4: &dyn Fn(usize, usize, u8, u8) -> f64,
    del: &dyn Fn(usize, usize, u8, u8) -> f64,
    t0: u64,
) -> Option<LmSolution> {
    if l_total == 0 {
        return (t0 >= 1).then(|| LmSolution {
            a: Vec::new(),
            b: Vec::new(),
            s: Vec::new(),
            deleted: Vec::new(),
            objective: 0.0,
            latency: 0,
        });
    }
    let s1: Stage1 = super::stage1::solve(t);
    let m = l_total.saturating_sub(1);
    let mut best: Option<LmSolution> = None;
    for b_bits in 0..(1u32 << m) {
        let mut b_set = Vec::new();
        for p in 0..m {
            if b_bits & (1 << p) != 0 {
                b_set.push(p + 1);
            }
        }
        let mut pts = vec![0usize];
        pts.extend(&b_set);
        pts.push(l_total);
        let nb = b_set.len();
        let n_blocks = nb + 1;
        for a_bits in 0..(1u32 << nb) {
            let state = |bound: usize| -> u8 {
                if bound == 0 || bound == l_total {
                    1
                } else {
                    let pos = b_set.iter().position(|&x| x == bound).unwrap();
                    ((a_bits >> pos) & 1) as u8
                }
            };
            'modes: for mode_bits in 0..(1u32 << n_blocks) {
                let mut obj = 0.0;
                let mut deleted: Vec<(usize, usize)> = Vec::new();
                for (bi, w) in pts.windows(2).enumerate() {
                    let (sa, sb) = (state(w[0]), state(w[1]));
                    let v = if mode_bits & (1 << bi) != 0 {
                        deleted.push((w[0], w[1]));
                        del(w[0], w[1], sa, sb)
                    } else {
                        imp4(w[0], w[1], sa, sb)
                    };
                    if v == NEG_INF {
                        continue 'modes;
                    }
                    obj += v;
                }
                // barriers: network ends, state-1 boundaries, deleted
                // endpoints.  Kept runs between consecutive barriers
                // price as one merged conv; deleted intervals are free.
                let mut barriers = vec![0usize, l_total];
                for &x in &b_set {
                    if state(x) == 1 {
                        barriers.push(x);
                    }
                }
                for &(i, j) in &deleted {
                    barriers.push(i);
                    barriers.push(j);
                }
                barriers.sort_unstable();
                barriers.dedup();
                let mut lat: u64 = 0;
                let mut s_set: Vec<usize> = Vec::new();
                for w in barriers.windows(2) {
                    if deleted.iter().any(|&(i, j)| (i, j) == (w[0], w[1])) {
                        continue; // identity: zero ticks, no S interior
                    }
                    if !s1.feasible(w[0], w[1]) {
                        continue 'modes;
                    }
                    lat = lat.saturating_add(s1.t_opt(w[0], w[1]));
                    s_set.extend(s1.s_opt(w[0], w[1]));
                }
                if lat >= t0 {
                    continue;
                }
                if best.as_ref().map_or(true, |bb| obj > bb.objective) {
                    let a: Vec<usize> =
                        b_set.iter().filter(|&&x| state(x) == 1).copied().collect();
                    s_set.extend(barriers[1..barriers.len() - 1].iter().copied());
                    s_set.sort_unstable();
                    s_set.dedup();
                    best = Some(LmSolution {
                        a,
                        b: b_set.clone(),
                        s: s_set,
                        deleted,
                        objective: obj,
                        latency: lat,
                    });
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_oracle_tiny_instance() {
        let mut t = LatTable::new(2);
        t.set(0, 1, 5);
        t.set(1, 2, 5);
        t.set(0, 2, 8);
        let mut imp = vec![vec![NEG_INF; 3]; 3];
        imp[0][1] = 0.0;
        imp[1][2] = 0.0;
        imp[0][2] = -1.0;
        // budget 9: only merging fits (lat 8 < 9, split needs 10);
        // budget 11: the split (lat 10, obj 0) becomes feasible and wins
        let m = solve_base(2, &t, &imp, 9).unwrap();
        assert!(m.a.is_empty());
        assert_eq!(m.latency, 8);
        let k = solve_base(2, &t, &imp, 11).unwrap();
        assert_eq!(k.a, vec![1]);
        assert_eq!(k.objective, 0.0);
    }

    #[test]
    fn extended_oracle_prefers_added_activation() {
        let mut t = LatTable::new(2);
        t.set(0, 1, 5);
        t.set(1, 2, 5);
        t.set(0, 2, 8);
        // boundary 1 with activation ON is worth more
        let f = |i: usize, j: usize, _a: u8, b: u8| -> f64 {
            match (i, j) {
                (0, 1) => {
                    if b == 1 {
                        0.5
                    } else {
                        0.0
                    }
                }
                (1, 2) => 0.0,
                (0, 2) => -1.0,
                _ => NEG_INF,
            }
        };
        let sol = solve_extended(2, &t, &f, 20).unwrap();
        assert_eq!(sol.b, vec![1]);
        assert_eq!(sol.a, vec![1]);
        assert!((sol.objective - 0.5).abs() < 1e-12);
    }
}
