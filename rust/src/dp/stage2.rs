//! Stage-2 DP (paper Algorithm 2): jointly optimal activation-keep set
//! A and merge set S under an integer latency budget T0.
//!
//!   D[l, t] = max_k  D[k, t - T_opt[k, l]] + I[k, l]
//!             s.t.   T_opt[0, k] + T_opt[k, l] < t
//!
//! Exactness: paper Propositions 4.1 / 4.2 — verified here against a
//! brute-force oracle in dp/brute.rs.  O(L^2 * T0).

use super::stage1::{Stage1, INF};

pub const NEG_INF: f64 = f64::NEG_INFINITY;

/// Importance of a contiguous block (k, l] with both endpoint
/// activations kept on.  NEG_INF marks invalid blocks.
pub trait Importance {
    fn imp(&self, k: usize, l: usize) -> f64;
}

impl<F: Fn(usize, usize) -> f64> Importance for F {
    fn imp(&self, k: usize, l: usize) -> f64 {
        self(k, l)
    }
}

#[derive(Debug, Clone)]
pub struct Solution {
    /// activation layers kept (ascending, subset of S)
    pub a: Vec<usize>,
    /// merge boundaries (ascending)
    pub s: Vec<usize>,
    /// surrogate objective value sum I
    pub objective: f64,
    /// total latency of the merged network (integer-scaled)
    pub latency: u64,
}

/// Algorithm 2's DP table, built once up to a maximum budget.  Column
/// `t` holds the optimum under the strict constraint `latency < t`, so
/// a single table answers EVERY budget `t0 <= t0_max`: cell values are
/// column-local (cell (l, t) only reads cells (k, t - seg)), hence
/// identical to what a fresh per-budget solve would compute.  This is
/// what makes the planner's one-pass frontier sweep exact.
#[derive(Debug, Clone)]
pub struct Stage2Table {
    pub l: usize,
    n_t: usize,
    d: Vec<f64>,
    /// parent k per (l, t); usize::MAX = none/base
    par: Vec<usize>,
}

/// Build the Algorithm 2 table for all budgets up to `t0_max`.
pub fn build<I: Importance>(l_total: usize, s1: &Stage1, imp: &I, t0_max: u64) -> Stage2Table {
    let n_t = t0_max as usize + 1;
    let mut d = vec![NEG_INF; (l_total + 1) * n_t];
    let mut par = vec![usize::MAX; (l_total + 1) * n_t];
    // D[0, t] = 0 for t >= 1 only: the empty prefix has latency exactly
    // 0, which satisfies the strict bound `latency < t` iff t >= 1
    // (matters for the degenerate L = 0 instance; for l >= 1 the k = 0
    // transition is already pruned to rem >= 1 by the t_opt check)
    for t in 1..n_t {
        d[t] = 0.0;
    }
    for l in 1..=l_total {
        let t_min = s1.t_opt(0, l);
        if t_min >= INF {
            continue;
        }
        for t in (t_min as usize + 1)..n_t {
            let mut best = NEG_INF;
            let mut best_k = usize::MAX;
            for k in 0..l {
                let seg = s1.t_opt(k, l);
                if seg >= INF || s1.t_opt(0, k) >= INF {
                    continue;
                }
                // feasibility: T_opt[0,k] + T_opt[k,l] < t
                if s1.t_opt(0, k).saturating_add(seg) >= t as u64 {
                    continue;
                }
                let rem = t - seg as usize;
                let prev = d[k * n_t + rem];
                if prev == NEG_INF {
                    continue;
                }
                let cand = prev + imp.imp(k, l);
                if cand > best {
                    best = cand;
                    best_k = k;
                }
            }
            d[l * n_t + t] = best;
            par[l * n_t + t] = best_k;
        }
    }
    Stage2Table { l: l_total, n_t, d, par }
}

impl Stage2Table {
    /// Largest budget this table can answer.
    pub fn t0_max(&self) -> u64 {
        (self.n_t - 1) as u64
    }

    /// Number of DP cells the table holds (planner build metrics).
    pub fn cells(&self) -> usize {
        self.d.len()
    }

    /// Optimal objective at strict budget `t0` (NEG_INF = infeasible).
    pub fn objective(&self, t0: u64) -> f64 {
        assert!(t0 <= self.t0_max(), "budget {t0} beyond table max {}", self.t0_max());
        self.d[self.l * self.n_t + t0 as usize]
    }

    /// Reconstruct the jointly optimal (A, S) at budget `t0 <= t0_max`.
    /// Identical to a fresh `solve` at `t0` (same table cells, same
    /// tie-breaking) — property-tested in planner::tests.
    pub fn extract(&self, s1: &Stage1, t0: u64) -> Option<Solution> {
        assert!(t0 <= self.t0_max(), "budget {t0} beyond table max {}", self.t0_max());
        let n_t = self.n_t;
        let mut l = self.l;
        let mut t = t0 as usize;
        if self.d[l * n_t + t] == NEG_INF {
            return None;
        }
        let objective = self.d[l * n_t + t];
        let mut a = Vec::new();
        let mut s = Vec::new();
        let mut latency: u64 = 0;
        while l > 0 {
            let k = self.par[l * n_t + t];
            if k == usize::MAX {
                return None; // inconsistent table
            }
            latency += s1.t_opt(k, l);
            s.extend(s1.s_opt(k, l));
            if k > 0 {
                a.push(k);
                s.push(k);
            }
            t -= s1.t_opt(k, l) as usize;
            l = k;
        }
        a.sort_unstable();
        s.sort_unstable();
        s.dedup();
        Some(Solution { a, s, objective, latency })
    }
}

/// Algorithm 2.  `t0` is the integer budget (strict: latency < t0).
pub fn solve<I: Importance>(l_total: usize, s1: &Stage1, imp: &I, t0: u64) -> Option<Solution> {
    build(l_total, s1, imp, t0).extract(s1, t0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::brute;
    use crate::dp::stage1::{self, LatTable};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    pub fn random_instance(
        rng: &mut Rng,
        l: usize,
    ) -> (LatTable, Vec<Vec<f64>>) {
        let mut t = LatTable::new(l);
        let mut imp = vec![vec![NEG_INF; l + 1]; l + 1];
        for i in 0..l {
            for j in i + 1..=l {
                let mergeable = j == i + 1 || rng.uniform() < 0.6;
                if mergeable {
                    t.set(i, j, 1 + rng.below(30) as u64);
                    imp[i][j] = -(rng.uniform() as f64) * (j - i) as f64;
                }
            }
        }
        (t, imp)
    }

    #[test]
    fn matches_brute_force_oracle() {
        forall(40, 31, |rng| {
            let l = 2 + rng.below(6);
            let (t, imp) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let t0 = 5 + rng.below(120) as u64;
            let f = |k: usize, j: usize| imp[k][j];
            let got = solve(l, &s1, &f, t0);
            let want = brute::solve_base(l, &t, &imp, t0);
            match (got, want) {
                (None, None) => Ok(()),
                (Some(g), Some(w)) => {
                    crate::prop_assert!(
                        (g.objective - w.objective).abs() < 1e-9,
                        "objective {} != brute {} (A={:?} vs {:?}, t0={})",
                        g.objective,
                        w.objective,
                        g.a,
                        w.a,
                        t0
                    );
                    crate::prop_assert!(
                        g.latency < t0,
                        "latency {} violates budget {}",
                        g.latency,
                        t0
                    );
                    Ok(())
                }
                (g, w) => Err(format!(
                    "feasibility mismatch: dp={:?} brute={:?} t0={}",
                    g.map(|x| x.objective),
                    w.map(|x| x.objective),
                    t0
                )),
            }
        });
    }

    #[test]
    fn s_is_latency_optimal_given_a() {
        // Proposition 4.2: the reconstructed S minimizes latency when A fixed
        forall(30, 32, |rng| {
            let l = 2 + rng.below(5);
            let (t, imp) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let t0 = 10 + rng.below(100) as u64;
            let f = |k: usize, j: usize| imp[k][j];
            if let Some(sol) = solve(l, &s1, &f, t0) {
                // optimal latency given A = sum of T_opt over A-segments
                let mut pts = vec![0usize];
                pts.extend(&sol.a);
                pts.push(l);
                let want: u64 = pts.windows(2).map(|w| s1.t_opt(w[0], w[1])).sum();
                crate::prop_assert!(
                    sol.latency == want,
                    "latency {} != optimal-given-A {}",
                    sol.latency,
                    want
                );
                // and S refines A exactly
                for a in &sol.a {
                    crate::prop_assert!(sol.s.contains(a), "A not subset of S");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn budget_monotonicity() {
        forall(20, 33, |rng| {
            let l = 2 + rng.below(5);
            let (t, imp) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let f = |k: usize, j: usize| imp[k][j];
            let mut prev = NEG_INF;
            for t0 in [5u64, 15, 40, 80, 200] {
                if let Some(sol) = solve(l, &s1, &f, t0) {
                    crate::prop_assert!(
                        sol.objective >= prev - 1e-12,
                        "objective not monotone in budget"
                    );
                    prev = sol.objective;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn one_table_answers_every_budget() {
        // the frontier-sweep invariant: extract(t0) from a table built
        // at t0_max equals a fresh per-budget solve, field for field
        forall(25, 34, |rng| {
            let l = 2 + rng.below(6);
            let (t, imp) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let f = |k: usize, j: usize| imp[k][j];
            let table = build(l, &s1, &f, 150);
            for t0 in [5u64, 17, 40, 88, 150] {
                let fresh = solve(l, &s1, &f, t0);
                let swept = table.extract(&s1, t0);
                match (fresh, swept) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        crate::prop_assert!(
                            a.a == b.a
                                && a.s == b.s
                                && a.objective == b.objective
                                && a.latency == b.latency,
                            "t0={t0}: fresh (A={:?} S={:?} obj={} lat={}) != swept \
                             (A={:?} S={:?} obj={} lat={})",
                            a.a,
                            a.s,
                            a.objective,
                            a.latency,
                            b.a,
                            b.s,
                            b.objective,
                            b.latency
                        );
                    }
                    (a, b) => {
                        return Err(format!(
                            "t0={t0}: feasibility mismatch fresh={:?} swept={:?}",
                            a.map(|x| x.objective),
                            b.map(|x| x.objective)
                        ))
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let mut t = LatTable::new(2);
        t.set(0, 1, 10);
        t.set(1, 2, 10);
        t.set(0, 2, 15);
        let s1 = stage1::solve(&t);
        let f = |_: usize, _: usize| 0.0;
        assert!(solve(2, &s1, &f, 10).is_none()); // needs >= 15 strictly
        assert!(solve(2, &s1, &f, 16).is_some());
    }

    #[test]
    fn paper_figure2_shape() {
        // a hand-checkable instance: keeping more activations costs latency
        let mut t = LatTable::new(3);
        t.set(0, 1, 4);
        t.set(1, 2, 4);
        t.set(2, 3, 4);
        t.set(0, 2, 6);
        t.set(1, 3, 6);
        t.set(0, 3, 7);
        let s1 = stage1::solve(&t);
        // importance: each kept boundary recovers 1.0 of accuracy
        let f = |k: usize, j: usize| -((j - k) as f64 - 1.0);
        // generous budget: keep everything
        let sol = solve(3, &s1, &f, 13).unwrap();
        assert_eq!(sol.a, vec![1, 2]);
        // tight budget: forced to merge it all
        let sol = solve(3, &s1, &f, 8).unwrap();
        assert!(sol.a.is_empty());
        assert_eq!(sol.latency, 7);
    }
}
