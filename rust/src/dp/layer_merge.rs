//! Layer-merge DP: the follow-up paper's joint (delete layers,
//! linearize activations) search space over the SAME inputs as the
//! two-stage DP — one latency table, one importance provider.
//!
//! LayerMerge (Kim et al., the same group's follow-up to the source
//! paper) enlarges the extended space of Appendix B.1 once more: a
//! block (k, l] may be KEPT (merged into one convolution, priced by the
//! stage-1 product) or DELETED (replaced by the identity — zero
//! latency, importance from a separate deletion view `del(i, j, a, b)`,
//! NEG_INF where deletion is structurally illegal).  Per-boundary
//! activation states d in {0, 1} carry over unchanged, so the state
//! jointly tracks (layer kept/deleted, activation kept/linearized).
//!
//! The recurrence extends Algorithm 4 with a zero-latency transition:
//!
//!   D[l, t, a] = max(
//!     max_{k, alpha}  D[k, t - T_opt[k, l], alpha] + I3[k, l, alpha, a],
//!     max_{k, alpha}  D[k, t,               alpha] + del[k, l, alpha, a])
//!
//! where I3 is the stage-3 product (optimal id-joint re-partition of a
//! kept run) shared with the extended solver.  Deleted blocks act as
//! merge BARRIERS: a merged convolution cannot span a hole, so kept
//! runs between deletions are priced by T_opt over exactly that run,
//! and a deleted block contributes zero ticks (it bypasses the >= 1
//! tick clamp — identity really is free).  Every extended-space
//! solution is a layer-merge solution with no deletions, so the
//! layer-merge optimum dominates the extended optimum by construction.
//!
//! Columns stay budget-local (cell (l, t) only reads cells at t or
//! t - seg), so ONE table built at t0_max answers every budget below it
//! — the same build(t0_max) + extract(t0) split as stage 2 / stage 4,
//! reused by the planner's frontier sweep.  Exactness is established
//! against the exhaustive joint enumeration in `dp/brute.rs`
//! (`solve_layer_merge`), property-tested in `planner::testkit`.

use super::extended::{solve_stage3, Importance4, Stage3};
use super::stage1::{Stage1, INF};
use super::stage2::NEG_INF;

/// The joint plan: kept activations A, block boundaries B, merge
/// boundaries S (deleted spans appear as their own S-segments), the
/// deleted spans themselves, the objective, and the merged-network
/// latency in ticks (kept runs only — deletions are free).
#[derive(Debug, Clone)]
pub struct LmSolution {
    pub a: Vec<usize>,
    pub b: Vec<usize>,
    pub s: Vec<usize>,
    /// deleted spans (i, j], ascending, disjoint
    pub deleted: Vec<(usize, usize)>,
    pub objective: f64,
    pub latency: u64,
}

/// The layer-merge DP table, built once up to a maximum budget.  As
/// with `stage2::Stage2Table` / `extended::Stage4Table`, column `t`
/// encodes the optimum under the strict constraint `latency < t` and
/// cells are column-local, so one table answers every budget
/// `t0 <= t0_max` identically to a fresh per-budget solve.
#[derive(Debug, Clone)]
pub struct LayerMergeTable {
    pub l: usize,
    n_t: usize,
    d: Vec<f64>,
    par_k: Vec<usize>,
    par_a: Vec<u8>,
    /// 0 = kept run (k, l], 1 = deleted block (k, l]
    par_mode: Vec<u8>,
}

/// Build the layer-merge table for all budgets up to `t0_max`.  `s3` is
/// the budget-independent stage-3 product over the KEEP importances
/// (shared with the extended solver); `del` is the deletion view.
pub fn build<D: Importance4>(
    l_total: usize,
    s1: &Stage1,
    s3: &Stage3,
    del: &D,
    t0_max: u64,
) -> LayerMergeTable {
    let n_t = t0_max as usize + 1;
    let idx = |l: usize, t: usize, a: usize| (l * n_t + t) * 2 + a;
    // hoist the deletion view into a dense matrix: the inner loop runs
    // n_t times per (l, k, alpha, a) cell and must not hit a map lookup
    let dix = |i: usize, j: usize, a: usize, b: usize| ((i * (l_total + 1) + j) * 2 + a) * 2 + b;
    let mut del4 = vec![NEG_INF; (l_total + 1) * (l_total + 1) * 4];
    for i in 0..l_total {
        for j in i + 1..=l_total {
            for a in 0..2 {
                for b in 0..2 {
                    del4[dix(i, j, a, b)] = del.imp4(i, j, a as u8, b as u8);
                }
            }
        }
    }
    let mut d = vec![NEG_INF; (l_total + 1) * n_t * 2];
    let mut par_k = vec![usize::MAX; (l_total + 1) * n_t * 2];
    let mut par_a = vec![0u8; (l_total + 1) * n_t * 2];
    let mut par_mode = vec![0u8; (l_total + 1) * n_t * 2];
    // boundary 0 is the network input; the empty prefix has latency
    // exactly 0, feasible under every strict budget t >= 1 (t = 0 stays
    // NEG_INF: latency >= 0 can never be < 0)
    for t in 1..n_t {
        d[idx(0, t, 0)] = 0.0;
        d[idx(0, t, 1)] = 0.0;
    }
    for l in 1..=l_total {
        // no t_min gating: unlike stage 2 / stage 4, boundary l may be
        // reachable BELOW T_opt[0, l] (deletions are free), so every
        // column from 1 up is live
        for t in 1..n_t {
            for a in 0..2usize {
                let mut best = NEG_INF;
                let mut bk = usize::MAX;
                let mut ba = 0u8;
                let mut bm = 0u8;
                for k in 0..l {
                    // boundary 0 has exactly one (virtual, on) state
                    let alphas: &[u8] = if k == 0 { &[1] } else { &[0, 1] };
                    // kept run (k, l]: costs T_opt, scores the stage-3
                    // optimal id-joint re-partition
                    let seg = s1.t_opt(k, l);
                    if seg < INF && (seg as usize) < t {
                        let rem = t - seg as usize;
                        for &alpha in alphas {
                            let prev = d[idx(k, rem, alpha as usize)];
                            if prev == NEG_INF {
                                continue;
                            }
                            let gain = s3.i_opt(k, l, alpha, a as u8);
                            if gain == NEG_INF {
                                continue;
                            }
                            let cand = prev + gain;
                            if cand > best {
                                best = cand;
                                bk = k;
                                ba = alpha;
                                bm = 0;
                            }
                        }
                    }
                    // deleted block (k, l]: zero ticks, same column t
                    // (cells for k < l at column t are already final)
                    let dv0 = del4[dix(k, l, 0, a)];
                    let dv1 = del4[dix(k, l, 1, a)];
                    for &alpha in alphas {
                        let gain = if alpha == 0 { dv0 } else { dv1 };
                        if gain == NEG_INF {
                            continue;
                        }
                        let prev = d[idx(k, t, alpha as usize)];
                        if prev == NEG_INF {
                            continue;
                        }
                        let cand = prev + gain;
                        if cand > best {
                            best = cand;
                            bk = k;
                            ba = alpha;
                            bm = 1;
                        }
                    }
                }
                d[idx(l, t, a)] = best;
                par_k[idx(l, t, a)] = bk;
                par_a[idx(l, t, a)] = ba;
                par_mode[idx(l, t, a)] = bm;
            }
        }
    }
    LayerMergeTable { l: l_total, n_t, d, par_k, par_a, par_mode }
}

impl LayerMergeTable {
    /// Largest budget this table can answer.
    pub fn t0_max(&self) -> u64 {
        (self.n_t - 1) as u64
    }

    /// Number of DP cells the table holds (planner build metrics).
    pub fn cells(&self) -> usize {
        self.d.len()
    }

    #[inline]
    fn idx(&self, l: usize, t: usize, a: usize) -> usize {
        (l * self.n_t + t) * 2 + a
    }

    /// Reconstruct the jointly optimal (A, B, S, deleted) at
    /// `t0 <= t0_max`.  Identical to a fresh `solve` at `t0` — the
    /// frontier byte-identity property in `planner::testkit`.
    pub fn extract(&self, s1: &Stage1, s3: &Stage3, t0: u64) -> Option<LmSolution> {
        assert!(t0 <= self.t0_max(), "budget {t0} beyond table max {}", self.t0_max());
        let l_total = self.l;
        let t0 = t0 as usize;
        if l_total == 0 {
            // empty network: latency exactly 0, feasible iff 0 < t0
            return (t0 >= 1).then(|| LmSolution {
                a: Vec::new(),
                b: Vec::new(),
                s: Vec::new(),
                deleted: Vec::new(),
                objective: 0.0,
                latency: 0,
            });
        }
        let a_last: usize =
            if self.d[self.idx(l_total, t0, 1)] >= self.d[self.idx(l_total, t0, 0)] {
                1
            } else {
                0
            };
        if self.d[self.idx(l_total, t0, a_last)] == NEG_INF {
            return None;
        }
        let objective = self.d[self.idx(l_total, t0, a_last)];
        let mut a_set = Vec::new();
        let mut b_set = Vec::new();
        let mut s_set = Vec::new();
        let mut deleted = Vec::new();
        let mut latency = 0u64;
        let (mut l, mut t, mut a) = (l_total, t0, a_last);
        while l > 0 {
            let k = self.par_k[self.idx(l, t, a)];
            let alpha = self.par_a[self.idx(l, t, a)];
            let mode = self.par_mode[self.idx(l, t, a)];
            if k == usize::MAX {
                return None;
            }
            if mode == 0 {
                // kept run: id joints become B boundaries only (merging
                // may cross them — Algorithm 4 semantics)
                for m in s3.b_opt(k, l, alpha, a as u8) {
                    b_set.push(m);
                }
                latency += s1.t_opt(k, l);
                s_set.extend(s1.s_opt(k, l));
                t -= s1.t_opt(k, l) as usize;
            } else {
                // deleted block: free, and BOTH endpoints are merge
                // barriers — the span is its own S-segment (the upper
                // endpoint l was pushed by the unit above, or is L)
                deleted.push((k, l));
            }
            if k > 0 {
                b_set.push(k);
                s_set.push(k);
                if alpha == 1 {
                    a_set.push(k);
                }
            }
            l = k;
            a = alpha as usize;
        }
        a_set.sort_unstable();
        b_set.sort_unstable();
        b_set.dedup();
        s_set.sort_unstable();
        s_set.dedup();
        deleted.reverse();
        Some(LmSolution { a: a_set, b: b_set, s: s_set, deleted, objective, latency })
    }
}

/// One-shot solve: stage 3 + table build + extract at `t0` (strict:
/// latency < t0).  `imp` is the keep view, `del` the deletion view.
pub fn solve<I: Importance4, D: Importance4>(
    l_total: usize,
    s1: &Stage1,
    imp: &I,
    del: &D,
    t0: u64,
) -> Option<LmSolution> {
    let s3 = solve_stage3(l_total, imp);
    build(l_total, s1, &s3, del, t0).extract(s1, &s3, t0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::stage1::{self, LatTable};

    #[test]
    fn deletion_bypasses_the_tick_floor() {
        // two layers, no merged (0,2] entry: the cheapest KEPT network
        // costs 20 ticks, but deleting (1,2] leaves only 10
        let mut t = LatTable::new(2);
        t.set(0, 1, 10);
        t.set(1, 2, 10);
        let s1 = stage1::solve(&t);
        let keep = |_: usize, _: usize, _: u8, _: u8| 0.0;
        let del = |i: usize, j: usize, _: u8, _: u8| {
            if (i, j) == (1, 2) {
                -0.5
            } else {
                NEG_INF
            }
        };
        // strict budget: 10 ticks does NOT fit t0 = 10, does fit 11
        assert!(solve(2, &s1, &keep, &del, 10).is_none());
        let sol = solve(2, &s1, &keep, &del, 11).unwrap();
        assert_eq!(sol.deleted, vec![(1, 2)]);
        assert_eq!(sol.latency, 10);
        assert!((sol.objective - -0.5).abs() < 1e-12);
        // the deleted span is its own S-segment: S = {1}, segments
        // (0,1] kept + (1,2] deleted
        assert_eq!(sol.s, vec![1]);
        // with room for both layers the keep plan wins (0.0 > -0.5)
        let sol = solve(2, &s1, &keep, &del, 21).unwrap();
        assert!(sol.deleted.is_empty());
        assert_eq!(sol.latency, 20);
    }

    #[test]
    fn whole_network_deletion_is_latency_zero() {
        let mut t = LatTable::new(2);
        t.set(0, 1, 10);
        t.set(1, 2, 10);
        let s1 = stage1::solve(&t);
        let keep = |_: usize, _: usize, _: u8, _: u8| 0.0;
        let del = |_: usize, _: usize, _: u8, _: u8| -1.0;
        // budget 1 tick: no conv fits, but deleting (0,2] whole does
        let sol = solve(2, &s1, &keep, &del, 1).unwrap();
        assert_eq!(sol.latency, 0);
        assert_eq!(sol.deleted, vec![(0, 2)]);
        assert!(sol.s.is_empty());
        // budget 0 is infeasible even for the free plan (strict <)
        assert!(solve(2, &s1, &keep, &del, 0).is_none());
    }

    #[test]
    fn no_deletions_degenerates_to_extended() {
        // del = NEG_INF everywhere: the layer-merge optimum must equal
        // the extended optimum exactly, plan for plan
        let mut t = LatTable::new(3);
        t.set(0, 1, 4);
        t.set(1, 2, 4);
        t.set(2, 3, 4);
        t.set(0, 2, 6);
        t.set(1, 3, 6);
        t.set(0, 3, 7);
        let s1 = stage1::solve(&t);
        let keep =
            |i: usize, j: usize, _a: u8, b: u8| -((j - i) as f64 - 1.0) + 0.05 * b as f64;
        let del = |_: usize, _: usize, _: u8, _: u8| NEG_INF;
        for t0 in [5u64, 8, 9, 13, 20] {
            let lm = solve(3, &s1, &keep, &del, t0);
            let ext = crate::dp::extended::solve(3, &s1, &keep, t0);
            match (lm, ext) {
                (None, None) => {}
                (Some(m), Some(e)) => {
                    assert!(
                        (m.objective - e.objective).abs() < 1e-12,
                        "t0={t0}: lm {} != ext {}",
                        m.objective,
                        e.objective
                    );
                    assert_eq!(m.latency, e.latency, "t0={t0}");
                    assert!(m.deleted.is_empty());
                }
                (m, e) => panic!(
                    "t0={t0}: feasibility diverges (lm {:?}, ext {:?})",
                    m.map(|x| x.objective),
                    e.map(|x| x.objective)
                ),
            }
        }
    }

    #[test]
    fn deleted_blocks_are_merge_barriers() {
        // three layers; merged (0,3] would be cheap (3 ticks) but
        // deleting the MIDDLE layer forbids merging across the hole:
        // kept runs (0,1] and (2,3] price separately (5 + 5)
        let mut t = LatTable::new(3);
        t.set(0, 1, 5);
        t.set(1, 2, 50);
        t.set(2, 3, 5);
        t.set(0, 3, 3);
        let s1 = stage1::solve(&t);
        let keep = |_: usize, _: usize, _: u8, _: u8| 0.0;
        let del = |i: usize, j: usize, _: u8, _: u8| {
            if (i, j) == (1, 2) {
                1.0 // deletion strictly helps here
            } else {
                NEG_INF
            }
        };
        let sol = solve(3, &s1, &keep, &del, 100).unwrap();
        assert_eq!(sol.deleted, vec![(1, 2)]);
        assert_eq!(sol.latency, 10, "kept runs must not merge across the hole");
        assert!((sol.objective - 1.0).abs() < 1e-12);
        // S isolates the deleted span: {1, 2}
        assert_eq!(sol.s, vec![1, 2]);
    }

    #[test]
    fn one_table_answers_every_budget() {
        let mut t = LatTable::new(3);
        t.set(0, 1, 4);
        t.set(1, 2, 6);
        t.set(2, 3, 4);
        t.set(1, 3, 8);
        let s1 = stage1::solve(&t);
        let keep = |i: usize, j: usize, a: u8, b: u8| {
            -0.3 * (j - i) as f64 + 0.1 * (a as f64 + b as f64)
        };
        let del = |i: usize, j: usize, _: u8, _: u8| {
            if j == i + 1 {
                -0.9
            } else {
                NEG_INF
            }
        };
        let s3 = solve_stage3(3, &keep);
        let table = build(3, &s1, &s3, &del, 40);
        for t0 in [0u64, 1, 3, 5, 9, 14, 40] {
            let fresh = solve(3, &s1, &keep, &del, t0);
            let swept = table.extract(&s1, &s3, t0);
            match (fresh, swept) {
                (None, None) => {}
                (Some(f), Some(w)) => {
                    assert_eq!(f.a, w.a, "t0={t0}");
                    assert_eq!(f.b, w.b, "t0={t0}");
                    assert_eq!(f.s, w.s, "t0={t0}");
                    assert_eq!(f.deleted, w.deleted, "t0={t0}");
                    assert_eq!(f.latency, w.latency, "t0={t0}");
                    assert!((f.objective - w.objective).abs() < 1e-12, "t0={t0}");
                }
                (f, w) => panic!(
                    "t0={t0}: feasibility diverges (fresh {:?}, swept {:?})",
                    f.map(|x| x.objective),
                    w.map(|x| x.objective)
                ),
            }
        }
    }
}
