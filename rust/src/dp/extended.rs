//! Extended-space DP (paper Appendix B.1, Algorithms 3 & 4).
//!
//! The extension lets block boundaries carry an explicit activation
//! state d in {0, 1} — in MobileNetV2 this ADDS a ReLU6 at linear
//! bottleneck boundaries, which DepthShrinker showed helps.  Stage 3
//! (Algorithm 3) optimally re-partitions a block range into importance
//! blocks joined at id boundaries; stage 4 (Algorithm 4) runs the
//! budgeted DP over (boundary, state) pairs.

use super::stage1::{Stage1, INF};
use super::stage2::NEG_INF;

/// (d_i, d_j)-indexed importance of block (i, j].  NEG_INF = invalid.
pub trait Importance4 {
    fn imp4(&self, i: usize, j: usize, a: u8, b: u8) -> f64;
}

impl<F: Fn(usize, usize, u8, u8) -> f64> Importance4 for F {
    fn imp4(&self, i: usize, j: usize, a: u8, b: u8) -> f64 {
        self(i, j, a, b)
    }
}

/// Output of Algorithm 3.
pub struct Stage3 {
    l: usize,
    /// i_opt[k][l][a][b]
    i_opt: Vec<f64>,
    /// joint[k][l][a][b] = m: last block is (m, l] with id joint at m;
    /// m == k means "single block"
    joint: Vec<usize>,
}

impl Stage3 {
    #[inline]
    fn idx(&self, k: usize, l: usize, a: u8, b: u8) -> usize {
        ((k * (self.l + 1) + l) * 2 + a as usize) * 2 + b as usize
    }

    pub fn i_opt(&self, k: usize, l: usize, a: u8, b: u8) -> f64 {
        self.i_opt[self.idx(k, l, a, b)]
    }

    /// Interior id-joint boundaries of the optimal partition (B_opt).
    pub fn b_opt(&self, k: usize, l: usize, a: u8, b: u8) -> Vec<usize> {
        let mut out = Vec::new();
        let mut hi = l;
        let mut bb = b;
        while hi > k {
            let m = self.joint[self.idx(k, hi, a, bb)];
            if m == k {
                break;
            }
            out.push(m);
            hi = m;
            bb = 0; // joints are id boundaries
        }
        out.reverse();
        out
    }
}

/// Algorithm 3: O(L^3) over the 4 endpoint-state combinations.
pub fn solve_stage3<I: Importance4>(l_total: usize, imp: &I) -> Stage3 {
    let mut s3 = Stage3 {
        l: l_total,
        i_opt: vec![NEG_INF; (l_total + 1) * (l_total + 1) * 4],
        joint: vec![0; (l_total + 1) * (l_total + 1) * 4],
    };
    for l in 1..=l_total {
        for k in (0..l).rev() {
            for a in 0..2u8 {
                for b in 0..2u8 {
                    // single block
                    let mut best = imp.imp4(k, l, a, b);
                    let mut best_m = k;
                    // split at an id joint m: (k, m] with (a, 0) + block (m, l] with (0, b)
                    for m in k + 1..l {
                        let head = s3.i_opt(k, m, a, 0);
                        let tail = imp.imp4(m, l, 0, b);
                        if head == NEG_INF || tail == NEG_INF {
                            continue;
                        }
                        let cand = head + tail;
                        if cand > best {
                            best = cand;
                            best_m = m;
                        }
                    }
                    let idx = s3.idx(k, l, a, b);
                    s3.i_opt[idx] = best;
                    s3.joint[idx] = best_m;
                }
            }
        }
    }
    s3
}

#[derive(Debug, Clone)]
pub struct ExtSolution {
    pub a: Vec<usize>,
    pub b: Vec<usize>,
    pub s: Vec<usize>,
    pub objective: f64,
    pub latency: u64,
}

/// Algorithm 4's DP table, built once up to a maximum budget.  As with
/// `stage2::Stage2Table`, column `t` encodes the optimum under the
/// strict constraint `latency < t` and cells are column-local, so one
/// table answers every budget `t0 <= t0_max` — the planner's frontier
/// sweep reuses it (and the budget-independent Stage3 product) across
/// all budget points.
#[derive(Debug, Clone)]
pub struct Stage4Table {
    pub l: usize,
    n_t: usize,
    d: Vec<f64>,
    par_k: Vec<usize>,
    par_a: Vec<u8>,
}

/// Build the Algorithm 4 table over (boundary, activation-state) for
/// all budgets up to `t0_max`.  `s3` is the budget-independent stage-3
/// product for the same importance (the importance itself is only read
/// through it).
pub fn build(l_total: usize, s1: &Stage1, s3: &Stage3, t0_max: u64) -> Stage4Table {
    let n_t = t0_max as usize + 1;
    // D[l][t][a]; parents (k, alpha)
    let idx = |l: usize, t: usize, a: usize| (l * n_t + t) * 2 + a;
    let mut d = vec![NEG_INF; (l_total + 1) * n_t * 2];
    let mut par_k = vec![usize::MAX; (l_total + 1) * n_t * 2];
    let mut par_a = vec![0u8; (l_total + 1) * n_t * 2];
    // t >= 1 only: the empty prefix (latency exactly 0) satisfies the
    // strict bound iff t >= 1 (matters for the degenerate L = 0 case;
    // l >= 1 transitions already require rem >= 1 via the t_opt prune)
    for t in 1..n_t {
        // boundary 0 is the network input: its "state" is fixed; both
        // slots hold 0 so k=0 transitions read D[0, t, alpha=1] too
        d[idx(0, t, 0)] = 0.0;
        d[idx(0, t, 1)] = 0.0;
    }
    for l in 1..=l_total {
        let t_min = s1.t_opt(0, l);
        if t_min >= INF {
            continue;
        }
        for t in (t_min as usize + 1)..n_t {
            for a in 0..2usize {
                let mut best = NEG_INF;
                let mut bk = usize::MAX;
                let mut ba = 0u8;
                for k in 0..l {
                    let seg = s1.t_opt(k, l);
                    if seg >= INF || s1.t_opt(0, k) >= INF {
                        continue;
                    }
                    if s1.t_opt(0, k).saturating_add(seg) >= t as u64 {
                        continue;
                    }
                    let rem = t - seg as usize;
                    // boundary 0 has exactly one (virtual, on) state
                    let alphas: &[u8] = if k == 0 { &[1] } else { &[0, 1] };
                    for &alpha in alphas {
                        let prev = d[idx(k, rem, alpha as usize)];
                        if prev == NEG_INF {
                            continue;
                        }
                        let gain = s3.i_opt(k, l, alpha, a as u8);
                        if gain == NEG_INF {
                            continue;
                        }
                        let cand = prev + gain;
                        if cand > best {
                            best = cand;
                            bk = k;
                            ba = alpha;
                        }
                    }
                }
                d[idx(l, t, a)] = best;
                par_k[idx(l, t, a)] = bk;
                par_a[idx(l, t, a)] = ba;
            }
        }
    }
    Stage4Table { l: l_total, n_t, d, par_k, par_a }
}

impl Stage4Table {
    /// Largest budget this table can answer.
    pub fn t0_max(&self) -> u64 {
        (self.n_t - 1) as u64
    }

    /// Number of DP cells the table holds (planner build metrics).
    pub fn cells(&self) -> usize {
        self.d.len()
    }

    #[inline]
    fn idx(&self, l: usize, t: usize, a: usize) -> usize {
        (l * self.n_t + t) * 2 + a
    }

    /// Reconstruct the jointly optimal (A, B, S) at `t0 <= t0_max`.
    /// Identical to a fresh `solve` at `t0` — property-tested in
    /// planner::tests.
    pub fn extract(&self, s1: &Stage1, s3: &Stage3, t0: u64) -> Option<ExtSolution> {
        assert!(t0 <= self.t0_max(), "budget {t0} beyond table max {}", self.t0_max());
        let l_total = self.l;
        let t0 = t0 as usize;
        // final state at l = L is fixed "on" (sigma_L handled by the probes)
        let a_last: usize =
            if self.d[self.idx(l_total, t0, 1)] >= self.d[self.idx(l_total, t0, 0)] {
                1
            } else {
                0
            };
        if self.d[self.idx(l_total, t0, a_last)] == NEG_INF {
            return None;
        }
        let objective = self.d[self.idx(l_total, t0, a_last)];
        let mut a_set = Vec::new();
        let mut b_set = Vec::new();
        let mut s_set = Vec::new();
        let mut latency = 0u64;
        let (mut l, mut t, mut a) = (l_total, t0, a_last);
        while l > 0 {
            let k = self.par_k[self.idx(l, t, a)];
            let alpha = self.par_a[self.idx(l, t, a)];
            if k == usize::MAX {
                return None;
            }
            // within-range id joints become B boundaries ONLY: merging may
            // cross an id joint, so S does not split there (Algorithm 4)
            for m in s3.b_opt(k, l, alpha, a as u8) {
                b_set.push(m);
            }
            latency += s1.t_opt(k, l);
            s_set.extend(s1.s_opt(k, l));
            if k > 0 {
                b_set.push(k);
                s_set.push(k);
                if alpha == 1 {
                    a_set.push(k);
                }
            }
            t -= s1.t_opt(k, l) as usize;
            l = k;
            a = alpha as usize;
        }
        a_set.sort_unstable();
        b_set.sort_unstable();
        b_set.dedup();
        s_set.sort_unstable();
        s_set.dedup();
        Some(ExtSolution { a: a_set, b: b_set, s: s_set, objective, latency })
    }
}

/// Algorithm 4: budgeted DP over (boundary, activation-state).
pub fn solve<I: Importance4>(
    l_total: usize,
    s1: &Stage1,
    imp: &I,
    t0: u64,
) -> Option<ExtSolution> {
    let s3 = solve_stage3(l_total, imp);
    build(l_total, s1, &s3, t0).extract(s1, &s3, t0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dp::brute;
    use crate::dp::stage1::{self, LatTable};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Random (T, I4) instance with probe-rule-shaped invalidity.
    fn random_instance(
        rng: &mut Rng,
        l: usize,
    ) -> (LatTable, Vec<f64>, Vec<bool>) {
        let mut t = LatTable::new(l);
        let mut valid = vec![false; (l + 1) * (l + 1)];
        let mut imp = vec![NEG_INF; (l + 1) * (l + 1) * 4];
        // random per-boundary "original activation is id" flags
        let orig_id: Vec<bool> = (0..=l).map(|_| rng.uniform() < 0.5).collect();
        for i in 0..l {
            for j in i + 1..=l {
                let mergeable = j == i + 1 || rng.uniform() < 0.6;
                if !mergeable {
                    continue;
                }
                t.set(i, j, 1 + rng.below(30) as u64);
                valid[i * (l + 1) + j] = true;
                for a in 0..2u8 {
                    for b in 0..2u8 {
                        // probe rules (specs.enumerate_probes)
                        if i == 0 && a == 0 {
                            continue;
                        }
                        if j == l && b == 0 {
                            continue;
                        }
                        if i > 0 && !orig_id[i] && a == 0 {
                            continue;
                        }
                        if j < l && !orig_id[j] && b == 0 {
                            continue;
                        }
                        if i > 0 && j < l && orig_id[i] && orig_id[j] && b == 0 {
                            continue;
                        }
                        let v = -(rng.uniform() as f64) * (j - i) as f64
                            + 0.1 * (a as f64 + b as f64);
                        imp[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize] = v;
                    }
                }
            }
        }
        (t, imp, valid)
    }

    #[test]
    fn matches_brute_force_oracle() {
        forall(30, 41, |rng| {
            let l = 2 + rng.below(5);
            let (t, imp, _valid) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let t0 = 5 + rng.below(100) as u64;
            let f = |i: usize, j: usize, a: u8, b: u8| -> f64 {
                imp[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize]
            };
            let got = solve(l, &s1, &f, t0);
            let want = brute::solve_extended(l, &t, &f, t0);
            match (got, want) {
                (None, None) => Ok(()),
                (Some(g), Some(w)) => {
                    crate::prop_assert!(
                        (g.objective - w.objective).abs() < 1e-9,
                        "objective {} != brute {} (B={:?} vs {:?}, A={:?} vs {:?}, t0={t0})",
                        g.objective,
                        w.objective,
                        g.b,
                        w.b,
                        g.a,
                        w.a
                    );
                    crate::prop_assert!(g.latency < t0, "budget violated");
                    Ok(())
                }
                (g, w) => Err(format!(
                    "feasibility mismatch: dp={:?} brute={:?} t0={t0}",
                    g.map(|x| x.objective),
                    w.map(|x| x.objective)
                )),
            }
        });
    }

    #[test]
    fn a_subset_of_b_and_of_s() {
        forall(20, 42, |rng| {
            let l = 3 + rng.below(4);
            let (t, imp, _) = random_instance(rng, l);
            let s1 = stage1::solve(&t);
            let f = |i: usize, j: usize, a: u8, b: u8| -> f64 {
                imp[((i * (l + 1) + j) * 2 + a as usize) * 2 + b as usize]
            };
            if let Some(sol) = solve(l, &s1, &f, 100) {
                for x in &sol.a {
                    crate::prop_assert!(sol.b.contains(x), "A not in B");
                    // A positions are real activations: merging cannot
                    // cross them, so they must be S boundaries
                    crate::prop_assert!(sol.s.contains(x), "A not in S");
                }
                // note: B \ A (id joints) need NOT be in S — merging may
                // cross an id joint (Algorithm 4)
            }
            Ok(())
        });
    }

    #[test]
    fn stage3_single_block_base() {
        let f = |i: usize, j: usize, _a: u8, _b: u8| -> f64 {
            if j == i + 1 {
                -1.0
            } else {
                NEG_INF
            }
        };
        let s3 = solve_stage3(3, &f);
        // (0,3] must split into three singleton blocks at id joints
        assert!((s3.i_opt(0, 3, 1, 1) - -3.0).abs() < 1e-12);
        assert_eq!(s3.b_opt(0, 3, 1, 1), vec![1, 2]);
    }

    #[test]
    fn added_activation_wins_when_valuable() {
        // two layers; boundary 1 originally id; activation there adds value
        let mut t = LatTable::new(2);
        t.set(0, 1, 5);
        t.set(1, 2, 5);
        t.set(0, 2, 6);
        let s1 = stage1::solve(&t);
        let f = |i: usize, j: usize, _a: u8, b: u8| -> f64 {
            match (i, j) {
                (0, 1) => {
                    if b == 1 {
                        1.0
                    } else {
                        0.0
                    }
                }
                (1, 2) => 0.0,
                (0, 2) => 0.2,
                _ => NEG_INF,
            }
        };
        let sol = solve(2, &s1, &f, 100).unwrap();
        assert_eq!(sol.a, vec![1]);
        assert!((sol.objective - 1.0).abs() < 1e-12);
    }
}
