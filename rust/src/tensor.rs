//! Dense f32 tensor — the host-side value type for parameters, merged
//! kernels, and data batches.  Row-major (C order), matching both numpy
//! and `xla::Literal` layouts, so conversions are flat copies.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn scalar(x: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Strides for row-major layout.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    pub fn at4_mut(&mut self, a: usize, b: usize, c: usize, d: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 4);
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        &mut self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Convert to an XLA literal (f32, same layout).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Read back from an XLA literal (must be f32).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::from_vec(&dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn indexing_row_major() {
        let mut t = Tensor::zeros(&[2, 3, 1, 2]);
        *t.at4_mut(1, 2, 0, 1) = 7.0;
        assert_eq!(t.data[1 * 6 + 2 * 2 + 0 + 1], 7.0);
        assert_eq!(t.at4(1, 2, 0, 1), 7.0);
    }

    #[test]
    fn strides() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
